"""Fused two-phase retrieval tests (ISSUE 7 acceptance):

  (a) the fused pipeline (on-device shortlist compaction + gather, no
      host sync between phases) is bit-identical to the PR 4
      host-boundary path at equal ``min_join`` — swept over min_join,
      mixed dtypes, interleaved ingest, and the all-filtered empty
      window, plus a hypothesis property sweep over random corpora;
  (b) the (Q-bucket, s-bucket) ladder bounds the fused compiled-program
      population (via the ``compile_count`` hook);
  (c) ``jax.transfer_guard("disallow")`` around dispatch -> collect
      proves zero host transfers between phases on both backends, with
      the host shortlist builder booby-trapped as a tripwire;
  (d) shortlist overflow is a protocol, not a failure: the service
      falls back to the host-boundary path bit-identically, grows the
      hint ladder, and accounts the extra syncs;
  (e) gather indices are int32 end-to-end, and ingest refuses to grow
      past the int32 index space.
"""

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    DiscoveryService,
    GroupMajorDistributedExecutor,
    MIN_SHORTLIST,
    RetryPolicy,
    ShortlistHints,
    ShortlistOverflow,
    SketchIndex,
    build_shortlists,
    compile_count,
    fused_shortlist_spec,
    inject_faults,
    stack_trains,
    stage_min_join,
)
from repro.core.discovery import index as index_mod
from repro.core.discovery import planner as planner_mod
from repro.core.discovery.index import _MAX_ROWS_I32, _DeviceStore
from repro.core.sketch import build_sketch

N_ROWS = 1200
SK_N = 64
KEY_SPACE = 3000  # small enough that candidates genuinely join
RNG = np.random.default_rng(7)


def _keys(seed=9, lo=0):
    raw = np.arange(lo, lo + N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


def _mixed_index(keys, y, rng, n_joinable=3, n_disjoint=3, n_disc=2):
    """Corpus spanning all estimator groups with a joinable core and a
    disjoint tail (the selectivity regime the gate exists for)."""
    index = SketchIndex(n=SK_N, method="tupsk")
    for i in range(n_joinable):
        index.add(f"cont{i}", "k", "v", keys,
                  (y + (0.2 + i) * rng.normal(size=N_ROWS))
                  .astype(np.float32), False)
    for i in range(n_disc):
        index.add(f"disc{i}", "k", "v", keys,
                  rng.integers(0, 4 + i, size=N_ROWS), True)
    for i in range(n_disjoint):
        other = _keys(seed=9, lo=(i + 1) * N_ROWS)
        index.add(f"far{i}", "k", "v", other,
                  rng.normal(size=N_ROWS).astype(np.float32), False)
    return index


def _train(keys, v, disc=False):
    return build_sketch(keys, v, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=disc)


def _queue(keys, y, rng, q, disc_every=3):
    out = []
    for i in range(q):
        noisy = y + (0.1 + 0.25 * i) * rng.normal(size=N_ROWS)
        if i % disc_every == disc_every - 1:
            out.append(_train(keys, (noisy > 0).astype(np.int64), True))
        else:
            out.append(_train(keys, noisy.astype(np.float32), False))
    return out


def _flat(res):
    return [(m.table, mi, js) for m, mi, js in res]


def _norm(triple, C):
    """Drop sentinel lanes and canonicalize order for bitwise compare."""
    v, gi, js = (np.asarray(a) for a in triple)
    keep = gi < C
    v, gi, js = v[keep], gi[keep], js[keep]
    order = np.argsort(gi, kind="stable")
    return v[order], gi[order], js[order]


class TestFusedParity:
    """Fused == host-boundary, bitwise, at every layer."""

    def test_index_query_min_join_sweep(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(0))
        for disc in (False, True):
            sk = _train(keys, (y > 0).astype(np.int64) if disc
                        else y, disc)
            for mj in (1, 4, 16, 200):
                fused = index.query(sk, top_k=6, min_join=mj)
                host = index.query(sk, top_k=6, min_join=mj, fused=False)
                assert _flat(fused) == _flat(host), (disc, mj)

    def test_query_many_interleaved_ingest(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(1)
        index = _mixed_index(keys, y, rng)
        sks = _queue(keys, y, rng, 5, disc_every=99)  # one dtype per batch
        for step in range(3):
            got = index.query_many(sks, top_k=5, min_join=4)
            want = index.query_many(sks, top_k=5, min_join=4, fused=False)
            assert [_flat(g) for g in got] == [_flat(w) for w in want]
            index.add(f"late{step}", "k", "v", keys,
                      (0.5 * y + rng.normal(size=N_ROWS))
                      .astype(np.float32), False)

    def test_all_filtered_empty_window(self):
        """A window where no candidate passes min_join: the fused path
        must deliver the same empty rankings, not trip its fence."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(2))
        sk = _train(keys, y)
        huge = N_ROWS + 1
        fused = index.query(sk, top_k=5, min_join=huge)
        host = index.query(sk, top_k=5, min_join=huge, fused=False)
        assert fused == [] and host == []
        # hints observed zero survivors without overflowing
        assert index.shortlist_hints.overflows == 0

    def test_service_submit_parity(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(3)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sks = _queue(keys, y, rng, 7)
        cold = svc.submit(sks, top_k=5, min_join=4)
        warm = svc.submit(sks, top_k=5, min_join=4)
        host = svc.submit(sks, top_k=5, min_join=4, fused=False)
        assert [_flat(r) for r in cold] == [_flat(r) for r in warm] \
            == [_flat(r) for r in host]
        assert svc.stats()["admission"]["fused_windows"] > 0

    @given(seed=st.integers(0, 2**16),
           min_join=st.sampled_from([1, 4, 32]),
           disc=st.booleans())
    @settings(max_examples=6, deadline=None)
    def test_property_random_corpora(self, seed, min_join, disc):
        rng = np.random.default_rng(seed)
        keys = _keys(seed=seed % 97)
        y = rng.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, rng,
                             n_joinable=2 + seed % 3,
                             n_disjoint=1 + seed % 2)
        sk = _train(keys, (y > 0).astype(np.int64) if disc else y, disc)
        fused = index.query(sk, top_k=5, min_join=min_join)
        host = index.query(sk, top_k=5, min_join=min_join, fused=False)
        assert _flat(fused) == _flat(host)


class TestExecutorFusedBitwise:
    """Executor-level: the fused triples equal the two-step
    prefilter -> host shortlist -> gather-and-score triples bitwise
    (values, indices, and join sizes), not merely same ranking."""

    def test_batched_fused_vs_host_shortlists(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(4))
        sk = _train(keys, y)
        plan = index.plan(False)
        C = plan.n_candidates
        bx = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        hints = ShortlistHints()
        for mj in (1, 5, 12):
            # overflow-retry loop: grow hints until the spec fits
            for _ in range(8):
                spec = fused_shortlist_spec(plan, hints, mj)
                handle = bx.fused_dispatch(plan, trains, spec, mj)
                try:
                    fused = handle.collect()
                    break
                except ShortlistOverflow:
                    for eid, seen in handle.observed.items():
                        hints.observe((False, eid, mj, False), seen,
                                      overflowed=True)
            else:
                pytest.fail("hints never converged")
            js_blocks = bx.prefilter_dispatch(plan, trains).collect()
            sls = build_shortlists(plan, js_blocks, mj)
            host = bx.shortlist_dispatch(plan, trains, sls).collect()
            for f, h in zip(fused, host):
                fv, fg, fj = _norm(f, C)
                hv, hg, hj = _norm(h, C)
                np.testing.assert_array_equal(fg, hg)
                np.testing.assert_array_equal(fv, hv)
                np.testing.assert_array_equal(fj, hj)
                assert fg.dtype == np.int32 == hg.dtype

    def test_fused_js_bitwise_vs_prefilter(self):
        """The fused handle's replayable join sizes (the overflow
        fallback's input) are bitwise the standalone prefilter's."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(5))
        sk = _train(keys, y)
        plan = index.plan(False)
        bx = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        spec = fused_shortlist_spec(plan, ShortlistHints(), 4)
        handle = bx.fused_dispatch(plan, trains, spec, 4)
        want = bx.prefilter_dispatch(plan, trains).collect()
        got = handle.js_blocks()
        assert len(got) == len(want)
        for (gp_g, js_g), (gp_w, js_w) in zip(got, want):
            assert gp_g is gp_w
            np.testing.assert_array_equal(np.asarray(js_g),
                                          np.asarray(js_w))


class TestCompileBound:
    def test_fused_program_population_bounded(self):
        """Same shapes + same ladder rungs => zero new compiles on a
        second sweep with different data."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(6)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4)

        def sweep(r):
            for q in (1, 3, 5):
                for mj in (1, 4):
                    svc.submit(_queue(keys, y, r, q), top_k=5, min_join=mj)

        sweep(np.random.default_rng(100))
        warm = compile_count()
        sweep(np.random.default_rng(200))
        assert compile_count() == warm


class TestOverflowProtocol:
    def _overflow_corpus(self):
        """> MIN_SHORTLIST joinable candidates in one estimator group,
        so cold hints (rung = MIN_SHORTLIST) must overflow."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(8)
        index = SketchIndex(n=SK_N, method="tupsk")
        for i in range(MIN_SHORTLIST + 4):
            index.add(f"cont{i}", "k", "v", keys,
                      (y + (0.2 + i) * rng.normal(size=N_ROWS))
                      .astype(np.float32), False)
        return index, keys, y

    def test_executor_raises_and_reports(self):
        index, keys, y = self._overflow_corpus()
        sk = _train(keys, y)
        plan = index.plan(False)
        bx = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        spec = fused_shortlist_spec(plan, ShortlistHints(), 1)
        handle = bx.fused_dispatch(plan, trains, spec, 1)
        with pytest.raises(ShortlistOverflow):
            handle.collect()
        assert max(handle.observed.values()) > MIN_SHORTLIST

    def test_service_falls_back_bit_identically_and_adapts(self):
        index, keys, y = self._overflow_corpus()
        svc = DiscoveryService(index=index, max_q_bucket=4)
        sk = _train(keys, y)
        base = svc.stats()["admission"]
        cold = svc.submit([sk], top_k=5, min_join=1)
        st1 = svc.stats()["admission"]
        # overflow fallback: 3 syncs (fence, join-size replay, final
        # collect), and the window does not count as fused
        assert st1["host_syncs"] - base["host_syncs"] == 3
        assert st1["fused_windows"] == base["fused_windows"]
        assert index.shortlist_hints.overflows > 0
        warm = svc.submit([sk], top_k=5, min_join=1)
        st2 = svc.stats()["admission"]
        assert st2["host_syncs"] - st1["host_syncs"] == 1
        assert st2["fused_windows"] - st1["fused_windows"] == 1
        host = svc.submit([sk], top_k=5, min_join=1, fused=False)
        st3 = svc.stats()["admission"]
        assert st3["host_syncs"] - st2["host_syncs"] == 2
        assert _flat(cold[0]) == _flat(warm[0]) == _flat(host[0])

    def test_fused_dispatch_fault_recovers_on_pr4_path(self):
        """The fused_dispatch fault site degrades to the host-boundary
        ladder (recovery rungs never re-enter the fused path) and stays
        bit-identical."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(9)
        index = _mixed_index(keys, y, rng)
        svc = DiscoveryService(index=index, max_q_bucket=4,
                               retry_policy=RetryPolicy(
                                   max_retries=1, sleep=lambda s: None))
        sks = _queue(keys, y, rng, 4)
        with inject_faults({"fused_dispatch": 1}):
            res, outs = svc.submit_safe(sks, top_k=5, min_join=4)
        assert all(o.ok for o in outs)
        assert any(o.retries > 0 or o.fallbacks > 0 for o in outs)
        want = svc.submit(sks, top_k=5, min_join=4, fused=False)
        assert [_flat(r) for r in res] == [_flat(w) for w in want]


@pytest.mark.transfer_guard
class TestTransferGuard:
    """The proof of the tentpole: dispatch -> collect completes under
    ``jax.transfer_guard("disallow")`` — no host round-trip between the
    phases — with ``build_shortlists`` booby-trapped so any silent
    fallback to the host path fails loudly."""

    def _setup(self, mesh=None):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(10))
        sk = _train(keys, y)
        # warm everything host-side: hints, compiled programs, the
        # staged min_join scalar, and the device-resident plan arrays
        index.query(sk, top_k=5, min_join=4, mesh=mesh)
        plan = index.plan(False)
        trains = stack_trains([index.train_arrays(sk)])
        stage_min_join(4)
        return index, plan, trains

    def test_batched_no_transfers_between_phases(self, monkeypatch):
        index, plan, trains = self._setup()

        def boom(*a, **k):  # tripwire
            raise AssertionError("host shortlist build on fused path")

        monkeypatch.setattr(planner_mod, "build_shortlists", boom)
        monkeypatch.setattr(index_mod, "build_shortlists", boom)
        bx = BatchedExecutor()
        spec = fused_shortlist_spec(plan, index.shortlist_hints, 4)
        bx.fused_dispatch(plan, trains, spec, 4).collect()  # warm compile
        with jax.transfer_guard("disallow"):
            handle = bx.fused_dispatch(plan, trains, spec, 4)
            triples = handle.collect()
        assert len(triples) == 1 and len(triples[0][0]) > 0

    def test_distributed_no_transfers_between_phases(self, monkeypatch):
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        index, plan, trains = self._setup(mesh=mesh)

        def boom(*a, **k):
            raise AssertionError("host shortlist build on fused path")

        monkeypatch.setattr(planner_mod, "build_shortlists", boom)
        monkeypatch.setattr(index_mod, "build_shortlists", boom)
        dist = GroupMajorDistributedExecutor(mesh)
        sharded = mesh.shape["data"] > 1
        spec = fused_shortlist_spec(
            plan, index.shortlist_hints, 4,
            multiple=mesh.shape["data"] if sharded else 1,
            sharded=sharded,
        )
        dist.fused_topk_dispatch(plan, trains, spec, 4, 5).collect()
        with jax.transfer_guard("disallow"):
            handle = dist.fused_topk_dispatch(plan, trains, spec, 4, 5)
            triples = handle.collect()
        assert len(triples) == 1 and len(triples[0][0]) > 0

    def test_fused_query_never_builds_host_shortlists(self, monkeypatch):
        """Index-level: the default (fused) query path must not touch
        the host shortlist builder at all; the forced host path must."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(11))
        sk = _train(keys, y)
        index.query(sk, top_k=5, min_join=4)  # warm hints (no overflow)
        calls = []
        real = index_mod.build_shortlists
        monkeypatch.setattr(
            index_mod, "build_shortlists",
            lambda *a, **k: calls.append(1) or real(*a, **k),
        )
        index.query(sk, top_k=5, min_join=4)
        assert calls == []
        index.query(sk, top_k=5, min_join=4, fused=False)
        assert calls == [1]


class TestInt32EndToEnd:
    def test_triples_are_int32(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _mixed_index(keys, y, np.random.default_rng(12))
        sk = _train(keys, y)
        plan = index.plan(False)
        assert all(gp.index.dtype == np.int32 for gp in plan.groups)
        bx = BatchedExecutor()
        trains = stack_trains([index.train_arrays(sk)])
        spec = fused_shortlist_spec(plan, index.shortlist_hints, 1000)
        for v, gi, js in bx.fused_dispatch(
                plan, trains, spec, 1000).collect():
            assert np.asarray(gi).dtype == np.int32
        js_blocks = bx.prefilter_dispatch(plan, trains).collect()
        sls = build_shortlists(plan, js_blocks, 4)
        assert all(sl.gidx.dtype == np.int32 for sl in sls
                   if sl is not None)

    def test_device_store_refuses_int32_overflow(self):
        store = _DeviceStore(cap_cols=SK_N)
        with pytest.raises(OverflowError):
            store.ensure_rows(_MAX_ROWS_I32 + 1)

    def test_index_commit_refuses_int32_overflow(self):
        keys = _keys()
        index = SketchIndex(n=SK_N, method="tupsk")

        class _Huge(list):
            def __len__(self):
                return _MAX_ROWS_I32

        index.meta = _Huge()
        with pytest.raises(OverflowError):
            index.add("t", "k", "v", keys,
                      RNG.normal(size=N_ROWS).astype(np.float32), False)
