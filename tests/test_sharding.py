"""Sharding-rule tests + multi-device parity via subprocess."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.parallel.compat import abstract_mesh, manual_axes, manual_axes_scope
from repro.parallel.decode_attention import decode_attention, _local_decode

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(4)


class TestParamSpecs:
    def test_rules_cover_all_archs(self):
        """Every param leaf of every arch matches some rule (matrix leaves
        must not silently fall through to full replication)."""
        for arch in M.list_archs():
            cfg = M.get_config(arch, smoke=True)
            shapes = M.abstract_params(cfg)
            specs = SH.param_specs(shapes)
            flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
            spec_leaves = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)
            )
            for (path, leaf), spec in zip(flat, spec_leaves):
                name = SH._path_str(path)
                # norm scales / biases are replicated by design
                if name.endswith("/scale") or name.endswith("/b"):
                    continue
                if leaf.ndim >= 2 and max(leaf.shape) >= 64:
                    assert any(e is not None for e in spec), (arch, name)

    def test_divisibility_validation(self):
        mesh = jax.make_mesh((1,), ("model",))
        # fake a 16-way axis via abstract mesh is awkward; test the logic
        mesh16 = abstract_mesh((16,), ("model",))
        spec = SH.validate_spec(P("model"), (8,), mesh16)
        assert spec == P(None)  # 8 not divisible by 16 -> replicate
        spec = SH.validate_spec(P("model"), (32,), mesh16)
        assert spec == P("model")

    def test_embedding_padded_vocab_shards(self):
        cfg = M.get_config("internvl2-26b")  # vocab 92553 (odd)
        assert cfg.padded_vocab_size % 256 == 0
        mesh16 = abstract_mesh((16, 16), ("data", "model"))
        spec = SH.validate_spec(
            P("model", "data"), (cfg.padded_vocab_size, cfg.d_model), mesh16
        )
        assert spec == P("model", "data")


class TestDecodeAttention:
    def test_local_matches_naive(self):
        B, S, Hkv, g, Dh = 2, 64, 2, 3, 16
        H = Hkv * g
        q = jnp.asarray(RNG.normal(size=(B, H, Dh)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
        pos = 40
        out = decode_attention(q, k, v, jnp.int32(pos), scale=0.25)
        # naive reference
        kf = np.repeat(np.asarray(k), g, axis=2)  # (B,S,H,Dh)
        vf = np.repeat(np.asarray(v), g, axis=2)
        s = np.einsum("bhd,bshd->bhs", np.asarray(q), kf) * 0.25
        s[:, :, pos + 1:] = -1e30
        w = np.exp(s - s.max(-1, keepdims=True))
        w /= w.sum(-1, keepdims=True)
        ref = np.einsum("bhs,bshd->bhd", w, vf)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)

    def test_merge_math_equals_single_shard(self):
        """Partial-softmax merge across a fake axis == single pass."""
        B, S, Hkv, Dh = 1, 32, 2, 8
        q = jnp.asarray(RNG.normal(size=(B, Hkv, Dh)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, S, Hkv, Dh)), jnp.float32)
        full = _local_decode(q, k, v, jnp.int32(S - 1), 0.3)
        # emulate 2 shards by manual merge
        import jax.numpy as jnp2
        def part(ks, vs, off):
            Bq, Hq, D = q.shape
            qf = q.reshape(B, Hkv, 1, D).astype(jnp.float32)
            sc = jnp.einsum("bkgd,bskd->bkgs", qf, ks) * 0.3
            live = (off + jnp.arange(ks.shape[1])) <= S - 1
            sc = jnp.where(live[None, None, None], sc, -1e30)
            m = jnp.max(sc, -1)
            p = jnp.exp(sc - m[..., None])
            return m, jnp.sum(p, -1), jnp.einsum("bkgs,bskd->bkgd", p, vs)
        m1, l1, o1 = part(k[:, :16], v[:, :16], 0)
        m2, l2, o2 = part(k[:, 16:], v[:, 16:], 16)
        mg = jnp.maximum(m1, m2)
        c1, c2 = jnp.exp(m1 - mg), jnp.exp(m2 - mg)
        merged = (o1 * c1[..., None] + o2 * c2[..., None]) / (
            (l1 * c1 + l2 * c2)[..., None]
        )
        np.testing.assert_allclose(
            np.asarray(full), np.asarray(merged.reshape(B, Hkv, Dh)), atol=1e-5
        )


class TestMultiDeviceParity:
    """Sharded train step == single-device train step (4 fake devices)."""

    SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import model as M
        from repro.train import optimizer as O, train_step as TS
        from repro.data.pipeline import TokenPipeline
        from repro.parallel.sharding import mesh_context, apply_named_sharding

        cfg = M.get_config("internlm2-1.8b", smoke=True)
        opt = O.adamw(weight_decay=0.01)
        sched = O.warmup_cosine(1e-3, 2, 20)
        pipe = TokenPipeline(cfg, batch=4, seq=32, seed=0)
        batches = [jax.tree_util.tree_map(jnp.asarray, pipe.next_batch())
                   for _ in range(5)]

        def run(mesh):
            with mesh_context(mesh):
                step = jax.jit(TS.build_train_step(cfg, opt, sched))
                state = TS.init_train_state(cfg, opt, jax.random.key(0))
                if mesh is not None:
                    state = state._replace(params=jax.device_put(
                        state.params, apply_named_sharding(state.params, mesh)))
                losses = []
                for b in batches:
                    state, m = step(state, b)
                    losses.append(float(m["loss"]))
            return losses

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        l_sharded = run(mesh)
        l_single = run(None)
        np.testing.assert_allclose(l_sharded, l_single, rtol=2e-4)
        print("PARITY-OK", l_sharded[-1])
    """)

    def test_sharded_equals_single(self):
        out = subprocess.run(
            [sys.executable, "-c", self.SCRIPT],
            capture_output=True, text=True, timeout=420,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "PARITY-OK" in out.stdout


class TestManualAxes:
    """shard_activation constraint filtering inside manual regions."""

    def test_scope_nesting_and_union(self):
        assert manual_axes() == frozenset()
        with manual_axes_scope({"pod"}):
            assert manual_axes() == frozenset({"pod"})
            with manual_axes_scope({"model"}):
                assert manual_axes() == frozenset({"pod", "model"})
            assert manual_axes() == frozenset({"pod"})
        assert manual_axes() == frozenset()

    def test_shard_map_shim_declares_manual(self):
        """The compat shim records axis_names (or all mesh axes when
        full-manual) for the body trace."""
        from jax.sharding import PartitionSpec as SP
        from repro.parallel.compat import shard_map

        mesh = jax.make_mesh((1,), ("model",))
        seen = []

        def body(x):
            seen.append(manual_axes())
            return x

        with mesh:
            jax.jit(shard_map(
                body, mesh=mesh, in_specs=(SP(),), out_specs=SP(),
                axis_names=set(), check=False,
            ))(jnp.ones(4))
            jax.jit(shard_map(
                body, mesh=mesh, in_specs=(SP("model"),),
                out_specs=SP("model"), check=False,
            ))(jnp.ones(4))
        assert seen[0] == frozenset()
        assert seen[1] == frozenset({"model"})
        assert manual_axes() == frozenset()

    @staticmethod
    def _constraint_axes(jaxpr) -> set:
        axes: set = set()
        for eqn in jaxpr.jaxpr.eqns:
            if eqn.primitive.name == "sharding_constraint":
                for entry in eqn.params["sharding"].spec:
                    if entry is None:
                        continue
                    axes.update(
                        entry if isinstance(entry, tuple) else (entry,)
                    )
        return axes

    def test_constraint_drops_manual_axes(self):
        """'batch' resolves to ('data',) under a manual 'pod' scope; the
        emitted constraint must not name the manual axis."""
        mesh = jax.make_mesh((1, 1), ("pod", "data"))

        # distinct fn objects per trace: the scope is a trace-time
        # thread-local (like mesh_context) and invisible to jax's
        # tracing cache, so re-tracing the same callable would alias.
        def fresh():
            return lambda x: SH.shard_activation(x, "batch", None)

        with SH.mesh_context(mesh):
            open_axes = self._constraint_axes(
                jax.make_jaxpr(fresh())(jnp.ones((4, 4)))
            )
            with manual_axes_scope({"pod"}):
                scoped_axes = self._constraint_axes(
                    jax.make_jaxpr(fresh())(jnp.ones((4, 4)))
                )
        assert "pod" in open_axes
        assert scoped_axes and "pod" not in scoped_axes

    def test_constraint_skipped_when_all_manual(self):
        """Full-manual scope: the hint disappears instead of demanding
        replication."""
        mesh = jax.make_mesh((1, 1), ("pod", "data"))
        with SH.mesh_context(mesh):
            with manual_axes_scope({"pod", "data"}):
                jaxpr = jax.make_jaxpr(
                    lambda x: SH.shard_activation(x, "batch", None)
                )(jnp.ones((4, 4)))
        assert "sharding_constraint" not in str(jaxpr)


class TestInt8EfMultiPod:
    """int8_ef compression lowers on a multi-pod mesh (4 fake devices)
    and tracks the uncompressed step: bitwise on step 1 (loss computed
    before compression), within quantization tolerance after."""

    SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import model as M
        from repro.train import optimizer as O, train_step as TS
        from repro.data.pipeline import TokenPipeline
        from repro.parallel.sharding import mesh_context, apply_named_sharding

        cfg = M.get_config("internlm2-1.8b", smoke=True)
        opt = O.adamw(weight_decay=0.01)
        sched = O.warmup_cosine(1e-3, 2, 20)
        pipe = TokenPipeline(cfg, batch=4, seq=32, seed=0)
        batches = [jax.tree_util.tree_map(jnp.asarray, pipe.next_batch())
                   for _ in range(4)]

        def run(compression):
            mesh = jax.make_mesh((2, 2), ("pod", "data"))
            with mesh_context(mesh):
                step = jax.jit(TS.build_train_step(
                    cfg, opt, sched, compression=compression))
                state = TS.init_train_state(
                    cfg, opt, jax.random.key(0), compression=compression)
                state = state._replace(params=jax.device_put(
                    state.params, apply_named_sharding(state.params, mesh)))
                losses = []
                for b in batches:
                    state, m = step(state, b)
                    losses.append(float(m["loss"]))
            return losses

        l_comp = run("int8_ef")
        l_ref = run(None)
        assert np.isclose(l_comp[0], l_ref[0], rtol=1e-5), (l_comp, l_ref)
        np.testing.assert_allclose(l_comp, l_ref, rtol=0.05)
        assert all(np.isfinite(l_comp))
        print("INT8EF-OK", l_comp[-1])
    """)

    def test_multipod_compression_lowers_and_tracks(self):
        out = subprocess.run(
            [sys.executable, "-c", self.SCRIPT],
            capture_output=True, text=True, timeout=420,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "INT8EF-OK" in out.stdout
