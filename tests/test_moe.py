"""MoE tests: dropless sort+ragged_dot dispatch vs a dense per-expert
reference, routing properties, shared experts, EP shard_map parity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.ffn import dense_ffn, moe_ffn
from repro.parallel.sharding import mesh_context

RNG = np.random.default_rng(9)

CFG = ModelConfig(
    name="moe-test", family="moe", num_layers=2, d_model=32, vocab_size=64,
    num_experts=8, top_k=2, moe_d_ff=16, aux_loss_coef=0.01,
)


def _dense_reference(cfg, p, x):
    """Every expert on every token, combined by router weights."""
    B, S, D = x.shape
    x_flat = x.reshape(-1, D)
    top_p, top_i, _ = moe_ffn.route(cfg, p, x_flat)
    out = np.zeros((B * S, D), np.float32)
    for e in range(cfg.num_experts):
        w_g = np.asarray(p["experts"]["w_gate"][e])
        w_u = np.asarray(p["experts"]["w_up"][e])
        w_d = np.asarray(p["experts"]["w_down"][e])
        h = (np.asarray(jax.nn.silu(x_flat @ w_g))) * np.asarray(x_flat @ w_u)
        y_e = h @ w_d
        for k in range(cfg.top_k):
            sel = np.asarray(top_i[:, k]) == e
            out[sel] += np.asarray(top_p[:, k])[sel, None] * y_e[sel]
    return out.reshape(B, S, D)


class TestDroplessDispatch:
    def test_matches_dense_reference(self):
        p = moe_ffn.init(CFG, jax.random.key(0))
        x = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
        out, aux = moe_ffn.apply(CFG, p, x)
        ref = _dense_reference(CFG, p, x)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4)
        assert float(aux) > 0

    def test_no_token_dropped(self):
        """Dropless property: even a fully imbalanced routing (all tokens
        to one expert) produces nonzero outputs for every token."""
        cfg = CFG
        p = moe_ffn.init(cfg, jax.random.key(1))
        # Rig the router so expert 3 wins for every token.
        w = np.zeros((32, 8), np.float32)
        w[:, 3] = 10.0
        p["router"]["w"] = jnp.asarray(w)
        x = jnp.asarray(RNG.normal(size=(1, 16, 32)), jnp.float32)
        out, _ = moe_ffn.apply(cfg, p, x)
        norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
        assert np.all(norms > 0)

    def test_norm_topk(self):
        cfg = CFG.with_overrides(norm_topk=True)
        p = moe_ffn.init(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
        top_p, _, _ = moe_ffn.route(cfg, p, x.reshape(-1, 32))
        np.testing.assert_allclose(np.asarray(jnp.sum(top_p, axis=-1)), 1.0,
                                   rtol=1e-5)

    def test_shared_experts_added(self):
        cfg = CFG.with_overrides(num_shared_experts=2)
        p = moe_ffn.init(cfg, jax.random.key(0))
        x = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
        out_with, _ = moe_ffn.apply(cfg, p, x)
        shared = dense_ffn.apply(cfg, p["shared"], x)
        p_no = {k: v for k, v in p.items() if k != "shared"}
        out_without, _ = moe_ffn.apply(cfg, p_no, x)
        np.testing.assert_allclose(
            np.asarray(out_with), np.asarray(out_without + shared), atol=1e-5
        )


class TestExpertParallel:
    def test_ep_matches_gspmd_single_device(self):
        """shard_map EP path on a 1x1 mesh must equal the plain path."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        p = moe_ffn.init(CFG, jax.random.key(2))
        x = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
        plain, aux1 = moe_ffn.apply(CFG, p, x)
        with mesh_context(mesh):
            ep, aux2 = moe_ffn.apply(CFG, p, x, impl="ep")
        np.testing.assert_allclose(np.asarray(plain), np.asarray(ep), atol=1e-4)
        assert float(aux1) == pytest.approx(float(aux2), abs=1e-6)

    def test_aux_loss_balanced_routing_near_one(self):
        """For a uniform router, the Switch aux loss ≈ 1 (its minimum)."""
        cfg = CFG.with_overrides(aux_loss_coef=1.0)
        p = moe_ffn.init(cfg, jax.random.key(3))
        p["router"]["w"] = jnp.zeros((32, 8))  # uniform probs
        x = jnp.asarray(RNG.normal(size=(4, 64, 32)), jnp.float32)
        _, aux = moe_ffn.apply(cfg, p, x)
        assert float(aux) == pytest.approx(1.0, abs=0.3)
