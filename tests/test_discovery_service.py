"""Serving-architecture tests: planner buckets, executors, multi-query
batching, and incremental ingest-while-serving.

The two load-bearing properties (ISSUE 2 acceptance):

  (a) ``query_many`` over Q train sketches is bit-identical to Q looped
      ``query`` calls (the batched executor's vmap lanes are
      data-parallel), and
  (b) incremental ``add`` after ``stacked()`` equals a from-scratch
      rebuild of the index — and moves only the new rows host->device
      (no full re-stack), asserted via the ingest transfer counters.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    GroupMajorDistributedExecutor,
    PartitionedLocalExecutor,
    SketchIndex,
    bucket_rows,
    score_batch_partitioned,
    stack_trains,
)
from repro.core.discovery.planner import MIN_BUCKET
from repro.core.sketch import build_sketch

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(11)
N_ROWS = 2000
SK_N = 64


def _keys(seed=9):
    raw = np.arange(N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


def _mixed_adds(keys, y, rng):
    """Candidate set spanning all four estimator groups."""
    return [
        ("cont_strong", keys,
         (2 * y + 0.05 * rng.normal(size=N_ROWS)).astype(np.float32), False),
        ("cont_noise", keys, rng.normal(size=N_ROWS).astype(np.float32), False),
        ("cont_weak", keys,
         (y + 2.0 * rng.normal(size=N_ROWS)).astype(np.float32), False),
        ("disc_dep", keys, (y > 0).astype(np.int64), True),
        ("disc_noise", keys, rng.integers(0, 6, size=N_ROWS), True),
    ]


def _build(adds):
    index = SketchIndex(n=SK_N, method="tupsk")
    for name, k, v, disc in adds:
        index.add(name, "k", "v", k, v, disc)
    return index


def _train(keys, y, y_discrete=False):
    return build_sketch(keys, y, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=y_discrete)


def _trains(keys, y, q, y_discrete=False):
    rng = np.random.default_rng(100 + q)
    out = []
    for i in range(q):
        yq = (y + (0.1 + 0.3 * i) * rng.normal(size=N_ROWS)).astype(np.float32)
        if y_discrete:
            out.append(_train(keys, (yq > 0).astype(np.int64), True))
        else:
            out.append(_train(keys, yq, False))
    return out


class TestPlannerBuckets:
    def test_ladder_is_pow2_and_shared(self):
        assert bucket_rows(1) == MIN_BUCKET
        assert bucket_rows(MIN_BUCKET) == MIN_BUCKET
        # every size in (b/2, b] lands on the same bucket b
        for g in (5, 8, 9, 13, 16, 17, 100):
            b = bucket_rows(g)
            assert b >= max(g, MIN_BUCKET)
            assert b & (b - 1) == 0  # power of two
            assert bucket_rows(b) == b
        # shard-count multiples are respected
        assert bucket_rows(10, multiple=4) % 4 == 0
        assert bucket_rows(10, multiple=3) % 3 == 0

    def test_group_shapes_stable_across_adds_within_bucket(self):
        """Adding a candidate inside the current bucket must not change
        any compiled-program input shape (no recompiles)."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(0)))
        p1 = index.plan(False)
        shapes1 = {g.est_id: g.arrays["keys"].shape for g in p1.groups}
        index.add("late", "k", "v", keys,
                  RNG.normal(size=N_ROWS).astype(np.float32), False)
        p2 = index.plan(False)
        shapes2 = {g.est_id: g.arrays["keys"].shape for g in p2.groups}
        assert shapes1 == shapes2  # 3 -> 4 continuous: same 8-row bucket

    def test_plan_cached_until_add(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(0)))
        p1 = index.plan(False)
        assert index.plan(False) is p1
        index.add("late", "k", "v", keys, y.copy(), False)
        assert index.plan(False) is not p1


class TestExecutorsAgree:
    @pytest.mark.parametrize("y_discrete", [False, True])
    def test_three_backends_identical(self, y_discrete):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(1)))
        sks = _trains(keys, y, 3, y_discrete)
        trains = stack_trains([index.train_arrays(sk) for sk in sks])
        plan = index.plan(y_discrete)
        mi_p, js_p = PartitionedLocalExecutor().execute(plan, trains)
        mi_b, js_b = BatchedExecutor().execute(plan, trains)
        np.testing.assert_array_equal(mi_p, mi_b)
        np.testing.assert_array_equal(js_p, js_b)
        mesh = jax.make_mesh((1,), ("data",))
        mi_d, js_d = GroupMajorDistributedExecutor(mesh).execute(plan, trains)
        np.testing.assert_array_equal(mi_p, mi_d)
        np.testing.assert_array_equal(js_p, js_d)

    def test_distributed_topk_matches_dense(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(1)))
        sk = _train(keys, y)
        trains = stack_trains([index.train_arrays(sk)])
        plan = index.plan(False)
        mesh = jax.make_mesh((1,), ("data",))
        ex = GroupMajorDistributedExecutor(mesh)
        mi, js = ex.execute(plan, trains)
        v, gi, jsz = ex.topk(plan, trains, 3)[0]
        best = np.argsort(-mi[0], kind="stable")[:3]
        np.testing.assert_array_equal(np.sort(gi), np.sort(best))
        np.testing.assert_array_equal(np.sort(v), np.sort(mi[0][best]))

    def test_mixed_target_batch_rejected(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(1)))
        sks = [_train(keys, y, False),
               _train(keys, (y > 0).astype(np.int64), True)]
        with pytest.raises(ValueError, match="target dtype"):
            index.query_many(sks)


class TestQueryManyBitIdentity:
    """Acceptance (a): query_many == Q looped query calls, bitwise."""

    @pytest.mark.parametrize("y_discrete", [False, True])
    @pytest.mark.parametrize("q", [1, 4])
    def test_query_many_equals_looped_query(self, y_discrete, q):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(2)))
        sks = _trains(keys, y, q, y_discrete)
        many = index.query_many(sks, top_k=5, min_join=4)
        loop = [index.query(sk, top_k=5, min_join=4) for sk in sks]
        assert len(many) == q
        for res_m, res_l in zip(many, loop):
            assert [(m.table, mi, js) for m, mi, js in res_m] == \
                   [(m.table, mi, js) for m, mi, js in res_l]

    def test_scores_bitwise_at_executor_level(self):
        """The raw (Q, C) matrix rows equal single-query runs bit for
        bit — stronger than result-list equality (no argsort slack)."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(3)))
        sks = _trains(keys, y, 4)
        trains = [index.train_arrays(sk) for sk in sks]
        plan = index.plan(False)
        mi_many, js_many = BatchedExecutor().execute(plan, stack_trains(trains))
        for qi, t in enumerate(trains):
            mi_one, js_one = PartitionedLocalExecutor().execute(plan, t)
            np.testing.assert_array_equal(mi_many[qi], mi_one[0])
            np.testing.assert_array_equal(js_many[qi], js_one[0])

    @given(seed=st.integers(0, 2**16), q=st.integers(1, 5))
    @settings(max_examples=10, deadline=None)
    def test_property_random_corpora(self, seed, q):
        rng = np.random.default_rng(seed)
        keys = _keys(seed % 7 + 1)
        y = rng.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, rng))
        sks = _trains(keys, y, q)
        many = index.query_many(sks, top_k=4, min_join=2)
        loop = [index.query(sk, top_k=4, min_join=2) for sk in sks]
        for res_m, res_l in zip(many, loop):
            assert [(m.table, mi, js) for m, mi, js in res_m] == \
                   [(m.table, mi, js) for m, mi, js in res_l]


class TestIncrementalIngest:
    """Acceptance (b): add-after-stacked is incremental and exact."""

    def test_add_after_stacked_moves_only_new_rows(self):
        """Cache-identity: an add between two stacked() calls uploads
        exactly one row — the device store is appended, never rebuilt."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(4)))
        C = len(index)
        first = index.stacked(False)
        assert index.stacked(False) is first  # cached, no re-copy
        assert index.ingest_stats["h2d_rows"] == C
        index.add("late", "k", "v", keys, y.copy(), False)
        fresh = index.stacked(False)
        assert fresh is not first  # version bump -> new view
        assert fresh["keys"].shape[0] == C + 1
        # THE no-full-re-stack assertion: one new row crossed the bus,
        # not C + 1 (the seed cleared the cache and re-uploaded all).
        assert index.ingest_stats["h2d_rows"] == C + 1
        assert index.ingest_stats["pending_rows"] == 0

    def test_add_after_plan_appends_group_store(self):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(4)))
        C = len(index)
        index.plan(False)
        assert index.ingest_stats["group_h2d_rows"] == C
        index.add("late", "k", "v", keys, y.copy(), False)
        index.plan(False)
        assert index.ingest_stats["group_h2d_rows"] == C + 1

    @pytest.mark.parametrize("y_discrete", [False, True])
    def test_incremental_equals_rebuild(self, y_discrete):
        """stacked() and query() after interleaved add/serve cycles match
        a from-scratch index holding the same candidates."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        adds = _mixed_adds(keys, y, np.random.default_rng(5))
        sk = _train(keys, (y > 0).astype(np.int64) if y_discrete else y,
                    y_discrete)
        index = _build(adds[:2])
        index.query(sk, top_k=3, min_join=2)  # force flush mid-growth
        index.stacked(y_discrete)
        for name, _, v, disc in adds[2:]:
            index.add(name, "k", "v", keys, v, disc)
        rebuilt = _build(adds)
        inc = index.stacked(y_discrete)
        ref = rebuilt.stacked(y_discrete)
        for name in ("keys", "vals_f", "vals_u", "mask", "est_id"):
            np.testing.assert_array_equal(
                np.asarray(inc[name]), np.asarray(ref[name]))
        r_inc = index.query(sk, top_k=5, min_join=2)
        r_ref = rebuilt.query(sk, top_k=5, min_join=2)
        assert [(m.table, mi, js) for m, mi, js in r_inc] == \
               [(m.table, mi, js) for m, mi, js in r_ref]

    def test_capacity_doubling_preserves_rows(self):
        """Grow past several capacity doublings; all rows intact."""
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        rng = np.random.default_rng(6)
        index = SketchIndex(n=SK_N, method="tupsk")
        index.add("c0", "k", "v", keys, y.copy(), False)
        index.stacked(False)  # flush at 1 row (bucket MIN_BUCKET)
        for i in range(1, 20):  # crosses 8 -> 16 -> 32
            index.add(f"c{i}", "k", "v", keys,
                      (y + i * rng.normal(size=N_ROWS)).astype(np.float32),
                      False)
        inc = index.stacked(False)
        assert index.ingest_stats["store_grows"] >= 1
        rebuilt = SketchIndex(n=SK_N, method="tupsk")
        rng = np.random.default_rng(6)
        rebuilt.add("c0", "k", "v", keys, y.copy(), False)
        for i in range(1, 20):
            rebuilt.add(f"c{i}", "k", "v", keys,
                        (y + i * rng.normal(size=N_ROWS)).astype(np.float32),
                        False)
        ref = rebuilt.stacked(False)
        for name in ("keys", "vals_f", "vals_u", "mask", "est_id"):
            np.testing.assert_array_equal(
                np.asarray(inc[name]), np.asarray(ref[name]))

    @given(order=st.lists(st.integers(0, 4), min_size=2, max_size=5,
                          unique=True))
    @settings(max_examples=8, deadline=None)
    def test_property_any_ingest_order(self, order):
        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        adds = _mixed_adds(keys, y, np.random.default_rng(7))
        chosen = [adds[i] for i in order]
        index = _build(chosen[:1])
        index.stacked(False)
        for a in chosen[1:]:
            index.add(a[0], "k", "v", keys, a[2], a[3])
        rebuilt = _build(chosen)
        inc, ref = index.stacked(False), rebuilt.stacked(False)
        for name in ("keys", "vals_f", "vals_u", "mask", "est_id"):
            np.testing.assert_array_equal(
                np.asarray(inc[name]), np.asarray(ref[name]))


class TestBackCompatScorers:
    def test_score_batch_partitioned_on_effective_stacked(self):
        """The functional wrapper still matches the switch scorer on the
        (now effective-key) stacked arrays."""
        from repro.core.discovery import score_batch

        keys = _keys()
        y = RNG.normal(size=N_ROWS).astype(np.float32)
        index = _build(_mixed_adds(keys, y, np.random.default_rng(8)))
        train = index.train_arrays(_train(keys, y))
        cands = index.stacked(False)
        mi_s, js_s = score_batch(train, cands)
        mi_p, js_p = score_batch_partitioned(train, cands)
        np.testing.assert_array_equal(np.asarray(mi_s), np.asarray(mi_p))
        np.testing.assert_array_equal(np.asarray(js_s), np.asarray(js_p))


class TestMultiShardParity:
    """Group-major distributed scoring on 4 fake CPU devices equals the
    local executor (subprocess — device count is fixed at jax init)."""

    SCRIPT = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax
        from repro.core import hashing
        from repro.core.discovery import (
            GroupMajorDistributedExecutor, PartitionedLocalExecutor,
            SketchIndex, stack_trains,
        )
        from repro.core.sketch import build_sketch

        N = 1200
        rng = np.random.default_rng(3)
        keys = np.asarray(hashing.murmur3_32_np(
            np.arange(N, dtype=np.uint32), seed=np.uint32(5)))
        y = rng.normal(size=N).astype(np.float32)
        index = SketchIndex(n=64, method="tupsk")
        for i in range(6):
            index.add(f"c{i}", "k", "v", keys,
                      (y + i * rng.normal(size=N)).astype(np.float32), False)
        index.add("d", "k", "v", keys, (y > 0).astype(np.int64), True)
        sk = build_sketch(keys, y, n=64, method="tupsk", side="train",
                          value_is_discrete=False)
        trains = stack_trains([index.train_arrays(sk)])
        plan = index.plan(False)
        mesh = jax.make_mesh((4,), ("data",))
        ex = GroupMajorDistributedExecutor(mesh)
        mi_d, js_d = ex.execute(plan, trains)
        mi_l, js_l = PartitionedLocalExecutor().execute(plan, trains)
        np.testing.assert_array_equal(mi_d, mi_l)
        np.testing.assert_array_equal(js_d, js_l)
        v, gi, js = ex.topk(plan, trains, 3)[0]
        best = np.argsort(-mi_l[0], kind="stable")[:3]
        np.testing.assert_array_equal(np.sort(gi), np.sort(best))
        res = index.query(sk, top_k=3, mesh=mesh, min_join=4)
        assert res[0][0].table == "c0", res

        # Multi-query on-device cross-group merge: Q=3 triples equal the
        # dense ranking per query, through real 4-shard programs.
        sks = [build_sketch(keys, (y + 0.2 * (q + 1)
                                   * rng.normal(size=N)).astype(np.float32),
                            n=64, method="tupsk", side="train",
                            value_is_discrete=False) for q in range(3)]
        tr3 = stack_trains([index.train_arrays(s) for s in sks])
        mi3, _ = PartitionedLocalExecutor().execute(plan, tr3)
        for q, (v, gi, js) in enumerate(ex.topk(plan, tr3, 3)):
            best = np.argsort(-mi3[q], kind="stable")[:3]
            np.testing.assert_array_equal(np.sort(gi), np.sort(best))

        # Service front-end over the mesh == looped mesh query.
        from repro.core.discovery import DiscoveryService
        svc = DiscoveryService(index=index, mesh=mesh, max_q_bucket=2)
        got = svc.submit(sks, top_k=3, min_join=4)
        want = [index.query(s, top_k=3, mesh=mesh, min_join=4) for s in sks]
        for g, w in zip(got, want):
            assert [(m.table, mi, js) for m, mi, js in g] == \
                   [(m.table, mi, js) for m, mi, js in w]

        # Two-phase over real 4-shard programs: the shard-local
        # prefilter + sharded shortlist gather-and-score + on-device
        # merge equals the dense local ranking at equal min_join, for
        # the index path and the service path — including after
        # interleaved ingest.
        flat = lambda r: [(m.table, mi, js) for m, mi, js in r]
        for s in sks:
            dense = index.query(s, top_k=3, min_join=4, prefilter=False)
            pref = index.query(s, top_k=3, min_join=4, mesh=mesh,
                               prefilter=True)
            assert flat(pref) == flat(dense)
        index.add("late", "k", "v", keys,
                  (0.5 * y + rng.normal(size=N)).astype(np.float32), False)
        got = svc.submit(sks, top_k=3, min_join=4)
        want = [index.query(s, top_k=3, min_join=4, prefilter=False)
                for s in sks]
        for g, w in zip(got, want):
            assert flat(g) == flat(w)
        adm = svc.stats()["admission"]
        assert adm["prefiltered"] > 0 and adm["cands_filtered_out"] >= 0
        print("SHARD-PARITY-OK")

        # Fused shard-local gather (ISSUE 7): the single-dispatch
        # pipeline — shard-local compaction + gather inside shard_map,
        # feeding the on-device cross-shard merge — equals the forced
        # host-boundary path and the dense reference, through real
        # 4-shard programs; the warm pass runs with zero host syncs
        # between phases (transfer guard + booby-trapped host builder).
        for s in sks:
            fz = index.query(s, top_k=3, min_join=4, mesh=mesh)
            hb = index.query(s, top_k=3, min_join=4, mesh=mesh,
                             fused=False)
            assert flat(fz) == flat(hb)
        got_f = svc.submit(sks, top_k=3, min_join=4)
        got_h = svc.submit(sks, top_k=3, min_join=4, fused=False)
        assert [flat(g) for g in got_f] == [flat(g) for g in got_h]
        assert svc.stats()["admission"]["fused_windows"] > 0

        from repro.core.discovery import (
            fused_shortlist_spec, stack_trains, stage_min_join,
        )
        from repro.core.discovery import planner as _pl
        import repro.core.discovery.index as _ixm
        _real_bs = _pl.build_shortlists
        def _boom(*a, **k):
            raise AssertionError("host shortlist build on fused path")
        _pl.build_shortlists = _boom
        _ixm.build_shortlists = _boom
        tr1 = stack_trains([index.train_arrays(sks[0])])
        # pre-replicate the staged trains onto the mesh: that h2d is
        # part of dispatch *setup*, not the inter-phase boundary the
        # guard polices
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(mesh, P())
        tr1 = {k: jax.device_put(v, rep) if hasattr(v, "shape") else v
               for k, v in tr1.items()}
        plan = index.plan(False)
        spec = fused_shortlist_spec(plan, index.shortlist_hints, 4,
                                    multiple=4, sharded=True)
        stage_min_join(4)
        ex.fused_topk_dispatch(plan, tr1, spec, 4, 3).collect()  # warm
        with jax.transfer_guard("disallow"):
            h = ex.fused_topk_dispatch(plan, tr1, spec, 4, 3)
            triples = h.collect()
        assert len(triples) == 1 and len(triples[0][0]) > 0
        _pl.build_shortlists = _real_bs
        _ixm.build_shortlists = _real_bs
        print("FUSED-SHARD-OK")

        # Fault isolation across the mesh: a persistent fault on the
        # distributed shortlist dispatch forces every bucket down one
        # rung to the single-process batched executor — results stay
        # bit-identical to the per-query dense reference and outcomes
        # carry the fallback rung.
        from repro.core.discovery import RetryPolicy, inject_faults
        svc2 = DiscoveryService(index=index, mesh=mesh, max_q_bucket=4,
                                retry_policy=RetryPolicy(
                                    max_retries=1, sleep=lambda s: None))
        # fused=False pins the host-boundary path so the armed
        # shortlist_dispatch site is actually on the primary rung
        with inject_faults({"shortlist_dispatch@distributed": "all"}):
            res, outs = svc2.submit_safe(sks, top_k=3, min_join=4,
                                         fused=False)
        want = [index.query(s, top_k=3, min_join=4, prefilter=False)
                for s in sks]
        for r, w in zip(res, want):
            assert flat(r) == flat(w)
        assert all(o.ok and o.rung == "batched" for o in outs)
        assert all(o.fallbacks == 1 for o in outs)
        adm2 = svc2.stats()["admission"]
        assert adm2["failed_buckets"] == 1
        assert adm2["fallbacks"] == 1 and adm2["lost_queries"] == 0
        print("FAULT-FALLBACK-OK")
    """)

    def test_four_shard_parity(self):
        out = subprocess.run(
            [sys.executable, "-c", self.SCRIPT],
            capture_output=True, text=True, timeout=420,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        )
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARD-PARITY-OK" in out.stdout
        assert "FUSED-SHARD-OK" in out.stdout
        assert "FAULT-FALLBACK-OK" in out.stdout
