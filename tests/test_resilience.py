"""Fault-isolated serving tests (ISSUE 6 acceptance):

  (a) admission validation quarantines malformed query sketches into
      structured ``QueryOutcome`` errors while the rest of the queue
      serves bit-identically to looped ``SketchIndex.query``;
  (b) an injected dispatch/collect fault in one (signature, Q-bucket)
      batch retries with bounded backoff, then degrades down the
      executor ladder — every rung bit-identical, every other bucket
      untouched — and ``stats()`` reports the quarantine / retry /
      fallback counts exactly (the Q=32 end-to-end acceptance test);
  (c) non-finite MI lanes are fenced to the materialized reference
      path instead of being ranked;
  (d) ``add_table`` is transactional (a poisoned middle column leaves
      the index untouched) and ``AdmissionStats`` stays consistent
      with delivered results across mid-submit failures.

The whole suite honors ``REPRO_FAULT_SEED`` (CI runs a small matrix):
the seed varies which query is poisoned, *how* it is poisoned, and the
fault harness's rng — the isolation invariants must hold for all.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import hashing
from repro.core.discovery import (
    BatchedExecutor,
    DiscoveryService,
    InjectedFault,
    QueryOutcome,
    RetryPolicy,
    SketchIndex,
    fence_nonfinite,
    inject_faults,
    stack_trains_host,
    validate_query,
)
from repro.core.discovery import executors as _ex
from repro.core.discovery import resilience
from repro.core.discovery.planner import PlanCache
from repro.core.discovery.resilience import FaultPlan
from repro.core.sketch import build_sketch

N_ROWS = 800
SK_N = 64
SEED = int(os.environ.get("REPRO_FAULT_SEED", "0"))
RNG = np.random.default_rng(1000 + SEED)

# Zero-sleep policy so retry/backoff tests run at full speed; the delay
# *schedule* is still exercised (delays() is computed and indexed).
FAST_RETRY = RetryPolicy(max_retries=2, sleep=lambda s: None)


def _keys(seed=9):
    raw = np.arange(N_ROWS, dtype=np.uint32)
    return np.asarray(hashing.murmur3_32_np(raw, seed=np.uint32(seed)))


KEYS = _keys()
Y = RNG.normal(size=N_ROWS)


def _mixed_index(n_cont=3, n_disc=2):
    index = SketchIndex(n=SK_N, method="tupsk")
    for i in range(n_cont):
        index.add(f"cont{i}", "k", "v", KEYS,
                  (Y + (0.2 + i) * RNG.normal(size=N_ROWS))
                  .astype(np.float32), False)
    for i in range(n_disc):
        index.add(f"disc{i}", "k", "v", KEYS,
                  RNG.integers(0, 4 + i, size=N_ROWS), True)
    return index


def _train(v, disc):
    return build_sketch(KEYS, v, n=SK_N, method="tupsk", side="train",
                        value_is_discrete=disc)


def _mixed_queue(q, disc_every=3):
    out = []
    for i in range(q):
        noisy = Y + (0.1 + 0.25 * i) * RNG.normal(size=N_ROWS)
        if i % disc_every == disc_every - 1:
            out.append(_train((noisy > 0).astype(np.int64), True))
        else:
            out.append(_train(noisy.astype(np.float32), False))
    return out


def _flat(res):
    return [(m.table, mi, js) for m, mi, js in res]


def _service(index, **kw):
    kw.setdefault("retry_policy", FAST_RETRY)
    return DiscoveryService(index=index, **kw)


def _poison(kind: str):
    """A query sketch that must be quarantined, by failure mode."""
    if kind == "nonfinite_values":
        sk = _train(np.ones(N_ROWS, np.float32), False)
        vals = sk.values.copy()
        vals[: max(1, sk.size // 4)] = np.nan
        return dataclasses.replace(sk, values=vals), "nonfinite_values"
    if kind == "empty_sketch":
        sk = _train(Y.astype(np.float32), False)
        return dataclasses.replace(
            sk, mask=np.zeros_like(sk.mask)), "empty_sketch"
    if kind == "capacity_mismatch":
        sk = build_sketch(KEYS, Y.astype(np.float32), n=SK_N // 2,
                          method="tupsk", side="train",
                          value_is_discrete=False)
        return sk, "capacity_mismatch"
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Fault-injection harness semantics
# ---------------------------------------------------------------------------


class TestFaultHarness:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultPlan({"warp_core": "all"})

    def test_no_nesting(self):
        with inject_faults({"collect": 1}):
            with pytest.raises(RuntimeError, match="does not nest"):
                with inject_faults({"collect": 1}):
                    pass

    def test_unarmed_is_noop(self):
        resilience.maybe_fault("collect")  # no active plan -> no raise

    def test_int_schedule_fails_first_n(self):
        plan = FaultPlan({"collect": 2})
        for _ in range(2):
            with pytest.raises(InjectedFault):
                plan.check("collect", None)
        plan.check("collect", None)  # third invocation passes
        assert plan.fired == {"collect": 2}

    def test_index_schedule(self):
        plan = FaultPlan({"collect": [1]})
        plan.check("collect", None)
        with pytest.raises(InjectedFault):
            plan.check("collect", None)
        plan.check("collect", None)

    def test_scoped_key_only_hits_its_scope(self):
        plan = FaultPlan({"dispatch@distributed": "all"})
        plan.check("dispatch", "batched")  # other scope: passes
        with pytest.raises(InjectedFault):
            plan.check("dispatch", "distributed")

    def test_unscoped_key_hits_every_scope(self):
        plan = FaultPlan({"dispatch": "all"})
        with pytest.raises(InjectedFault):
            plan.check("dispatch", "batched")
        with pytest.raises(InjectedFault):
            plan.check("dispatch", "distributed")


# ---------------------------------------------------------------------------
# Admission validation + quarantine
# ---------------------------------------------------------------------------


class TestValidation:
    @pytest.fixture(scope="class")
    def index(self):
        return _mixed_index()

    def test_valid_sketch_passes(self, index):
        assert validate_query(_train(Y.astype(np.float32), False),
                              index) is None

    @pytest.mark.parametrize(
        "kind", ["nonfinite_values", "empty_sketch", "capacity_mismatch"]
    )
    def test_error_codes(self, index, kind):
        sk, code = _poison(kind)
        got = validate_query(sk, index)
        assert got is not None and got[0] == code

    def test_not_a_sketch(self, index):
        got = validate_query(object(), index)
        assert got is not None and got[0] == "invalid_sketch"

    def test_ragged_arrays(self, index):
        sk = _train(Y.astype(np.float32), False)
        bad = dataclasses.replace(sk, mask=np.ones(3, bool))
        got = validate_query(bad, index)
        assert got is not None and got[0] == "invalid_sketch"

    def test_unknown_dtype_flag(self, index):
        sk = _train(Y.astype(np.float32), False)
        bad = dataclasses.replace(sk, value_is_discrete=1)
        got = validate_query(bad, index)
        assert got is not None and got[0] == "unknown_dtype"

    def test_quarantine_preserves_other_results(self, index):
        svc = _service(index)
        queue = _mixed_queue(6)
        baseline = svc.submit(queue, top_k=5, min_join=4)
        bad, code = _poison("nonfinite_values")
        res, outs = svc.submit_safe(queue + [bad], top_k=5, min_join=4)
        assert res[-1] is None
        assert outs[-1].status == "quarantined"
        assert outs[-1].error == code and not outs[-1].ok
        assert [_flat(r) for r in res[:-1]] == [_flat(r) for r in baseline]
        assert all(o.ok for o in outs[:-1])
        assert svc.admission.quarantined == 1

    def test_all_quarantined(self, index):
        svc = _service(index)
        bad, _ = _poison("empty_sketch")
        res, outs = svc.submit_safe([bad], top_k=5)
        assert res == [None]
        assert outs[0].status == "quarantined"
        assert svc.admission.batches == 0


# ---------------------------------------------------------------------------
# Retry + executor-ladder fallback
# ---------------------------------------------------------------------------


class TestRecovery:
    @pytest.fixture(scope="class")
    def index(self):
        return _mixed_index()

    @pytest.fixture(scope="class")
    def baseline(self, index):
        queue = _mixed_queue(5)
        svc = _service(index)
        return queue, svc.submit(queue, top_k=5, min_join=4)

    def _assert_clean_parity(self, svc, queue, baseline, outs, res,
                             rung=None):
        assert all(o.ok for o in outs)
        assert [_flat(r) for r in res] == [_flat(r) for r in baseline]
        if rung is not None:
            assert {o.rung for o in outs} == {rung}

    def test_transient_fault_retries_same_rung(self, index, baseline):
        queue, base = baseline
        svc = _service(index)
        # One-shot fault: the first phase-2 dispatch dies, the first
        # retry of that same bucket succeeds — no ladder descent.
        # fused=False pins the host-boundary path these sites live on
        # (the fused pipeline's sites are covered in
        # test_fused_two_phase.py)
        with inject_faults({"shortlist_dispatch": [0]}) as plan:
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        fused=False)
        assert plan.fired == {"shortlist_dispatch": 1}
        self._assert_clean_parity(svc, queue, base, outs, res)
        st = svc.admission
        assert st.failed_buckets == 1
        assert st.retries == 1 and st.fallbacks == 0
        hit = [o for o in outs if o.retries]
        assert hit and all(o.rung == "batched" for o in hit)

    def test_persistent_fault_falls_back_to_reference(
            self, index, baseline):
        queue, base = baseline
        svc = _service(index)
        with inject_faults({"shortlist_dispatch": "all"}):
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        fused=False)
        self._assert_clean_parity(svc, queue, base, outs, res,
                                  rung="reference")
        st = svc.admission
        # 2 dtype buckets x (2 retries on the batched rung, then one
        # descent to the hook-free reference loop).
        assert st.failed_buckets == 2
        assert st.retries == 4 and st.fallbacks == 2
        assert st.lost_queries == 0

    @pytest.mark.parametrize("site", ["stack_h2d", "prefilter_dispatch"])
    def test_other_sites_recover(self, index, baseline, site):
        queue, base = baseline
        svc = _service(index)
        with inject_faults({site: [0]}):
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        fused=False)
        self._assert_clean_parity(svc, queue, base, outs, res)
        assert svc.admission.retries >= 1

    def test_collect_fault_recovers(self, index, baseline):
        queue, base = baseline
        svc = _service(index)
        # collect invocations: phase-1 of bucket A = 0, phase-1 of
        # bucket B = 1, phase-2 of A = 2 ... fault A's phase-2 sync.
        with inject_faults({"collect": [2]}):
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        fused=False)
        self._assert_clean_parity(svc, queue, base, outs, res)
        assert svc.admission.retries >= 1

    def test_dense_path_dispatch_fault(self, index):
        queue = _mixed_queue(4)
        svc = _service(index)
        base = svc.submit(queue, top_k=5, min_join=4, prefilter=False)
        with inject_faults({"dispatch": [0]}):
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        prefilter=False)
        self._assert_clean_parity(svc, queue, base, outs, res)

    def test_ladder_exhaustion_yields_failed_outcomes(
            self, index, baseline, monkeypatch):
        queue, base = baseline
        svc = _service(index)

        def boom(*a, **kw):
            raise RuntimeError("reference rung down")

        # Kill the batched rung at its earliest site and the reference
        # rung via its executor: nothing can deliver.
        monkeypatch.setattr(
            _ex.PartitionedLocalExecutor, "execute", boom)
        with inject_faults({"stack_h2d": "all"}):
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4)
        assert all(r is None for r in res)
        assert all(o.status == "failed" for o in outs)
        assert all(o.error == "ladder_exhausted" for o in outs)
        st = svc.admission
        assert st.lost_queries == len(queue)
        assert st.batches == 0  # nothing delivered -> nothing committed
        monkeypatch.undo()
        # The service is not wedged: the next clean submit delivers.
        res2, outs2 = svc.submit_safe(queue, top_k=5, min_join=4)
        self._assert_clean_parity(svc, queue, base, outs2, res2)

    def test_plan_failure_isolated(self):
        svc = _service(SketchIndex(n=SK_N))  # empty corpus
        res, outs = svc.submit_safe(
            [_train(Y.astype(np.float32), False)], top_k=5)
        assert res == [None]
        assert outs[0].status == "failed"
        assert outs[0].error == "plan_failed"


# ---------------------------------------------------------------------------
# Numeric fences
# ---------------------------------------------------------------------------


class TestNumericFence:
    @pytest.fixture(scope="class")
    def index(self):
        return _mixed_index()

    def test_fence_repairs_bit_identically(self, index):
        sk = _train(Y.astype(np.float32), False)
        plan = index.plan(False, k=3)
        mi, js = BatchedExecutor(k=3).execute(plan, stack_trains_host([sk]))
        v, jrow = mi[0].copy(), js[0]
        lanes = np.flatnonzero(jrow >= 4)[:3]
        assert lanes.size, "corpus must have joinable candidates"
        v[lanes] = np.nan
        fixed, n = fence_nonfinite(
            v, np.arange(len(index)), jrow, index, sk, 4, 3)
        assert n == lanes.size
        np.testing.assert_array_equal(fixed, mi[0])

    def test_fence_ignores_ineligible_lanes(self, index):
        # NaN in a lane below min_join (or a sentinel lane) must not be
        # demoted — the ranking layer never reads it.
        sk = _train(Y.astype(np.float32), False)
        C = len(index)
        v = np.full(C, np.nan, np.float32)
        js = np.zeros(C, np.int32)
        fixed, n = fence_nonfinite(v, np.arange(C), js, index, sk, 4, 3)
        assert n == 0

    def test_scores_site_drives_fence_end_to_end(self, index):
        queue = _mixed_queue(5)
        svc = _service(index)
        base = svc.submit(queue, top_k=5, min_join=4)
        with inject_faults({"scores": 2}, seed=SEED) as plan:
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4)
        assert plan.corrupted > 0
        assert [_flat(r) for r in res] == [_flat(r) for r in base]
        assert sum(o.nonfinite_lanes for o in outs) == plan.corrupted
        assert svc.admission.nonfinite_lanes == plan.corrupted


# ---------------------------------------------------------------------------
# Transactional ingest (satellite: add_table atomicity)
# ---------------------------------------------------------------------------


class _FakeColumn:
    def __init__(self, values, discrete, poisoned=False):
        self._values = values
        self._discrete = discrete
        self._poisoned = poisoned

    @property
    def is_discrete(self):
        return self._discrete

    def key_codes(self, seed=0):
        return KEYS

    def value_array(self):
        if self._poisoned:
            raise RuntimeError("storage backend lost this column")
        return self._values


class _FakeTable:
    """Duck-typed Table: key column + value columns, one optionally
    poisoned mid-iteration."""

    name = "faketab"

    def __init__(self, cols):
        self._cols = {"k": _FakeColumn(KEYS, True), **cols}

    def __getitem__(self, name):
        return self._cols[name]

    def pairs(self, key_column):
        return [(key_column, c) for c in self._cols if c != key_column]


class TestTransactionalIngest:
    def _table(self, poison_middle):
        return _FakeTable({
            "a": _FakeColumn(Y.astype(np.float32), False),
            "b": _FakeColumn(Y.astype(np.float32), False,
                             poisoned=poison_middle),
            "c": _FakeColumn(RNG.integers(0, 4, N_ROWS), True),
        })

    def test_poisoned_middle_column_rolls_back(self):
        index = _mixed_index()
        sk = _train(Y.astype(np.float32), False)
        before_len = len(index)
        before_version = index._version
        before_res = _flat(index.query(sk, top_k=5, min_join=4))
        with pytest.raises(RuntimeError, match="lost this column"):
            index.add_table(self._table(poison_middle=True), "k")
        assert len(index) == before_len
        assert index._version == before_version
        assert _flat(index.query(sk, top_k=5, min_join=4)) == before_res

    def test_capacity_poison_rolls_back(self):
        # A mid-table *validation* failure (not a storage error) must
        # also leave nothing behind: capacity mismatch on column b.
        index = _mixed_index()
        tab = self._table(poison_middle=False)
        tab._cols["b"] = _FakeColumn(
            Y[: N_ROWS // 2].astype(np.float32), False)
        tab._cols["b"].key_codes = lambda seed=0: KEYS[: N_ROWS // 2]
        before_len = len(index)
        with pytest.raises(Exception):
            index.add_table(tab, "k")
        assert len(index) == before_len

    def test_clean_table_commits_all(self):
        index = _mixed_index()
        before = len(index)
        index.add_table(self._table(poison_middle=False), "k")
        assert len(index) == before + 3
        names = [m.table for m in index.meta[-3:]]
        assert names == ["faketab"] * 3

    def test_flush_fault_leaves_store_consistent(self):
        index = _mixed_index()
        sk = _train(Y.astype(np.float32), False)
        base = _flat(index.query(sk, top_k=5, min_join=4))
        index.add("late", "k", "v", KEYS,
                  (Y + 0.05 * RNG.normal(size=N_ROWS)).astype(np.float32),
                  False)
        with inject_faults({"flush": "all"}):
            with pytest.raises(InjectedFault):
                index.query(sk, top_k=5, min_join=4)
        # The fault fired before any store mutation: the next query
        # flushes the same pending rows and serves the grown corpus.
        after = _flat(index.query(sk, top_k=5, min_join=4))
        assert len(index) == 6  # 3 cont + 2 disc + "late"
        assert index.ingest_stats["pending_rows"] == 0
        assert "late" in [t for t, _, _ in after]
        del base


# ---------------------------------------------------------------------------
# Stats consistency (satellite: no corruption on mid-submit raise)
# ---------------------------------------------------------------------------


class TestStatsConsistency:
    def test_legacy_submit_counts_failure_and_stays_consistent(self):
        index = _mixed_index()
        svc = _service(index)
        queue = [_train((Y + 0.3 * RNG.normal(size=N_ROWS))
                        .astype(np.float32), False) for _ in range(3)]
        with inject_faults({"shortlist_dispatch": "all"}):
            with pytest.raises(InjectedFault):
                svc.submit(queue, top_k=5, min_join=4, fused=False)
        st = svc.admission
        # Arrival counters committed, delivery counters untouched —
        # the failed submit delivered nothing and claims nothing.
        assert st.submits == 1 and st.submitted == 3
        assert st.failed_buckets == 1
        assert st.batches == 0 and st.padded_lanes == 0
        assert st.prefiltered == 0 and st.cands_considered == 0
        # A clean retry delivers and commits exactly one bucket.
        svc.submit(queue, top_k=5, min_join=4)
        assert st.batches == 1
        assert st.padded_lanes == 1  # 3 queries -> Q-bucket 4
        assert st.prefiltered == 3

    def test_plan_cache_counts_build_failures(self):
        cache = PlanCache(4)

        def boom():
            raise RuntimeError("no plan for you")

        with pytest.raises(RuntimeError):
            cache.lookup(0, False, 4, boom)
        assert cache.build_failures == 1
        assert cache.misses == 0 and len(cache) == 0
        assert cache.stats["build_failures"] == 1


# ---------------------------------------------------------------------------
# End-to-end acceptance: Q=32 mixed burst, one poisoned query, one
# injected bucket fault.
# ---------------------------------------------------------------------------


class TestEndToEndIsolation:
    def test_q32_burst_poison_plus_bucket_fault(self):
        index = _mixed_index()
        svc = _service(index)
        queue = _mixed_queue(32)
        cont_idx = [i for i in range(32) if i % 3 != 2]
        rng = np.random.default_rng(SEED)
        poison_at = int(rng.choice(cont_idx))
        kind = ["nonfinite_values", "empty_sketch",
                "capacity_mismatch"][SEED % 3]
        bad, code = _poison(kind)
        queue[poison_at] = bad

        # Reference truth: per-query SketchIndex.query over the same
        # corpus (skipping the poisoned slot).
        expected = {
            i: _flat(index.query(queue[i], top_k=5, min_join=4, k=svc.k))
            for i in range(32) if i != poison_at
        }

        # shortlist_dispatch invocation order: continuous bucket's
        # phase-2 dispatch is 0 (the burst starts with a continuous
        # query), the discrete bucket's is 1.  [0, 2, 3] kills the
        # continuous bucket's primary attempt and both its batched-rung
        # retries, forcing one descent to the reference rung; the
        # discrete bucket never faults.
        with inject_faults({"shortlist_dispatch": [0, 2, 3]},
                           seed=SEED) as plan:
            res, outs = svc.submit_safe(queue, top_k=5, min_join=4,
                                        fused=False)
        assert plan.fired == {"shortlist_dispatch": 3}

        # (1) the poisoned query: structured outcome, no result.
        assert res[poison_at] is None
        assert outs[poison_at].status == "quarantined"
        assert outs[poison_at].error == code

        # (2) the other 31: bit-identical to the looped reference.
        for i, want in expected.items():
            assert outs[i].ok, outs[i]
            assert _flat(res[i]) == want, f"query {i} diverged"

        # (3) rung accounting: continuous bucket fell to the reference
        # loop, the discrete bucket served at the primary rung.
        for i in range(32):
            if i == poison_at:
                continue
            if i % 3 == 2:
                assert outs[i].rung == "batched"
                assert outs[i].retries == 0 and outs[i].fallbacks == 0
            else:
                assert outs[i].rung == "reference"
                assert outs[i].retries == 2 and outs[i].fallbacks == 1

        # (4) stats report the recovery exactly.
        st = svc.stats()["admission"]
        assert st["quarantined"] == 1
        assert st["failed_buckets"] == 1
        assert st["retries"] == 2
        assert st["fallbacks"] == 1
        assert st["lost_queries"] == 0
        assert st["submitted"] == 31
        assert st["batches"] == 2  # both buckets delivered
        assert st["nonfinite_lanes"] == 0


# ---------------------------------------------------------------------------
# Scheduler chaos (ISSUE 9): the micro-batch tier's fault sites under
# the same REPRO_FAULT_SEED matrix — a faulted coalesced bucket walks
# the retry/fallback ladder while every other caller's outcome stays
# bit-identical to a fault-free run.
# ---------------------------------------------------------------------------


class TestSchedulerChaos:
    @pytest.fixture(scope="class")
    def index(self):
        return _mixed_index()

    def _sched_service(self, index):
        svc = _service(index)
        return svc, svc.scheduler(start=False)

    def test_window_timer_stall_loses_no_queries(self, index):
        """A stalled coalesce tick: queries stay queued, the stall is
        counted, and the next healthy tick serves them bit-identically."""
        svc, sched = self._sched_service(index)
        queue = _mixed_queue(6)
        solo = svc.submit(queue, top_k=5, min_join=4)
        handles = [sched.submit_async(q, top_k=5, min_join=4)
                   for q in queue]
        stalls = 1 + SEED % 2
        with inject_faults({"window_timer": stalls}) as plan:
            for _ in range(stalls):
                assert sched.run_pending() == 0
                assert not any(h.done() for h in handles)
            assert sched.run_pending() == len(queue)
        assert plan.fired == {"window_timer": stalls}
        assert sched.stats_.timer_stalls == stalls
        assert all(h.outcome().ok for h in handles)
        assert [_flat(h.result()) for h in handles] == \
            [_flat(r) for r in solo]
        svc.close()

    def test_staging_fault_walks_ladder_neighbors_untouched(self, index):
        """``staging`` dead for the whole window: the faulted coalesced
        buckets descend the executor ladder to the reference rung, yet
        every caller's results stay bit-identical and no caller sees a
        failure."""
        svc, sched = self._sched_service(index)
        queue = _mixed_queue(6)
        solo = svc.submit(queue, top_k=5, min_join=4)
        handles = [sched.submit_async(q, top_k=5, min_join=4)
                   for q in queue]
        with inject_faults({"staging": "all"}, seed=SEED):
            sched.run_pending()
        outs = [h.outcome() for h in handles]
        assert all(o.ok for o in outs)
        assert {o.rung for o in outs} == {"reference"}
        assert all(o.retries == FAST_RETRY.max_retries for o in outs)
        assert all(o.fallbacks == 1 for o in outs)
        assert [_flat(h.result()) for h in handles] == \
            [_flat(r) for r in solo]
        svc.close()

    def test_staging_fault_single_bucket_isolated(self, index):
        """One-shot ``staging`` fault: only the first coalesced bucket
        pays a retry; the other bucket's callers serve clean at the
        primary rung — no cross-caller blast radius."""
        svc, sched = self._sched_service(index)
        queue = _mixed_queue(6)  # 4 continuous + 2 discrete -> 2 buckets
        solo = svc.submit(queue, top_k=5, min_join=4)
        handles = [sched.submit_async(q, top_k=5, min_join=4)
                   for q in queue]
        with inject_faults({"staging": [0]}) as plan:
            sched.run_pending()
        assert plan.fired == {"staging": 1}
        outs = [h.outcome() for h in handles]
        assert all(o.ok and o.rung == "batched" for o in outs)
        hit = [o for o in outs if o.retries]
        clean = [o for o in outs if not o.retries]
        assert hit and clean  # exactly one bucket paid the retry
        assert all(o.fallbacks == 0 for o in outs)
        assert [_flat(h.result()) for h in handles] == \
            [_flat(r) for r in solo]
        svc.close()

    def test_ingest_midflight_fault_spares_inflight_window(self, index):
        """A faulted ingest fails its *caller* (structured, at the
        ``add`` call) while the window already in flight collects
        bit-identically against its dispatch-time corpus — and the
        index took nothing."""
        svc, sched = self._sched_service(index)
        queue = _mixed_queue(4)
        solo = svc.submit(queue, top_k=5, min_join=4)
        before_len = len(svc)
        handles = [sched.submit_async(q, top_k=5, min_join=4)
                   for q in queue]
        sched.run_pending(collect=False)  # window in flight
        with inject_faults({"ingest_midflight": "all"}):
            with pytest.raises(InjectedFault):
                sched.add("late", "k", "v", KEYS,
                          Y.astype(np.float32), False)
        assert len(svc) == before_len
        sched.run_pending()  # collect the in-flight window
        assert all(h.outcome().ok for h in handles)
        assert [_flat(h.result()) for h in handles] == \
            [_flat(r) for r in solo]
        # the tier is not wedged: a clean ingest + query still works
        sched.add("late", "k", "v", KEYS, Y.astype(np.float32), False)
        assert len(svc) == before_len + 1
        h = sched.submit_async(_train(Y.astype(np.float32), False),
                               top_k=before_len + 1, min_join=4)
        sched.run_pending()
        assert "late" in [m.table for m, _, _ in h.result()]
        svc.close()
