"""Flash-KSG knn_stats tests: kernel/fallback/oracle parity, estimator
equivalence with the materialized pairwise_cheb path, and the O(P·block)
memory guarantee (no P×P intermediate, asserted on the jaxpr)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import estimators
from repro.kernels.knn_stats.ops import (
    K_MAX,
    ball_counts,
    knn_radius_counts,
    knn_smallest,
    knn_with_counts,
)
from repro.kernels.knn_stats.ref import ball_counts_ref, knn_smallest_ref

RNG = np.random.default_rng(11)


def _sample(P, tie_frac=0.3):
    """Continuous marginals with repeated-value plateaus and padding."""
    x = RNG.normal(size=P).astype(np.float32)
    y = np.round(RNG.normal(size=P), 1).astype(np.float32)  # ties in y
    ties = RNG.uniform(size=P) < tie_frac
    x[ties] = np.round(x[ties], 0)  # ties in x too
    mask = RNG.uniform(size=P) > 0.15
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


class TestKnnSmallest:
    @pytest.mark.parametrize("P", [7, 64, 200, 513])
    @pytest.mark.parametrize("mode", ["joint", "class"])
    def test_fallback_matches_oracle(self, P, mode):
        x, y, m = _sample(P)
        if mode == "class":
            x = jnp.asarray(RNG.integers(0, 5, size=P).astype(np.float32))
        knn, cnt = knn_smallest(x, y, m, k=3, mode=mode, use_kernel=False)
        knn_r, cnt_r = knn_smallest_ref(x, y, m, k=3, mode=mode)
        np.testing.assert_array_equal(np.asarray(knn), np.asarray(knn_r))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
        # ascending per row (inf padding -> finite sentinel, else inf-inf=nan)
        kk = np.where(np.isinf(np.asarray(knn)), np.float32(3e38), np.asarray(knn))
        assert np.all(np.diff(kk, axis=1) >= 0)

    @pytest.mark.parametrize("P,block", [(64, 128), (300, 128), (256, 256)])
    @pytest.mark.parametrize("mode", ["joint", "class"])
    def test_kernel_matches_oracle(self, P, block, mode):
        """Pallas kernel (interpret on CPU) == naive oracle, both modes."""
        x, y, m = _sample(P)
        if mode == "class":
            x = jnp.asarray(RNG.integers(0, 5, size=P).astype(np.float32))
        knn, cnt = knn_smallest(
            x, y, m, k=4, mode=mode, use_kernel=True, block=block
        )
        knn_r, cnt_r = knn_smallest_ref(x, y, m, k=4, mode=mode)
        np.testing.assert_array_equal(np.asarray(knn), np.asarray(knn_r))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))

    def test_all_invalid_rows_are_inf(self):
        x, y, _ = _sample(16)
        m = jnp.zeros(16, bool)
        knn, cnt = knn_smallest(x, y, m, k=3, use_kernel=False)
        assert np.all(np.isinf(np.asarray(knn)))
        assert np.all(np.asarray(cnt) == 0)


class TestBallCounts:
    @pytest.mark.parametrize("P", [7, 64, 200, 513])
    def test_fallback_matches_oracle(self, P):
        x, y, m = _sample(P)
        r = jnp.asarray(RNG.uniform(0, 2, size=P).astype(np.float32))
        got = ball_counts(x, y, m, r, use_kernel=False)
        want = ball_counts_ref(x, y, m, r)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("P,block", [(64, 128), (300, 128)])
    def test_kernel_matches_oracle(self, P, block):
        x, y, m = _sample(P)
        r = jnp.asarray(RNG.uniform(0, 2, size=P).astype(np.float32))
        got = ball_counts(x, y, m, r, use_kernel=True, block=block)
        want = ball_counts_ref(x, y, m, r)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_y_only_mode(self, use_kernel):
        """which='y' returns the same y_lt with zeroed x/tie counts."""
        P = 100
        x, y, m = _sample(P)
        r = jnp.asarray(RNG.uniform(0, 2, size=P).astype(np.float32))
        got = ball_counts(x, y, m, r, which="y",
                          use_kernel=use_kernel, block=128)
        want = ball_counts_ref(x, y, m, r)
        np.testing.assert_array_equal(np.asarray(got.y_lt), np.asarray(want[1]))
        for field in (got.x_lt, got.x_eq, got.y_eq, got.j_eq):
            assert not np.any(np.asarray(field))


def _iter_eqn_shapes(jaxpr):
    """All output shapes of all equations, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield aval.shape
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqn_shapes(sub)


def _sub_jaxprs(val):
    if hasattr(val, "jaxpr"):  # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):  # Jaxpr
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _sub_jaxprs(item)


class TestMemoryModel:
    """The flash-KSG guarantee: no P×P intermediate, O(P·block) only."""

    P = 512
    BLOCK = 128

    def _assert_no_pxp(self, fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args)
        shapes = list(_iter_eqn_shapes(jaxpr.jaxpr))
        offenders = [s for s in shapes if len(s) >= 2 and
                     s[-1] == self.P and s[-2] == self.P]
        assert not offenders, f"P×P intermediates found: {offenders}"
        # sanity: the streamed (P, block) tiles DO appear
        assert any(s[-2:] == (self.P, self.BLOCK) for s in shapes
                   if len(s) >= 2)

    def test_knn_smallest_never_materializes(self):
        x, y, m = _sample(self.P)
        self._assert_no_pxp(
            lambda a, b, c: knn_smallest(
                a, b, c, k=3, use_kernel=False, block=self.BLOCK
            )[0],
            x, y, m,
        )

    def test_ball_counts_never_materializes(self):
        x, y, m = _sample(self.P)
        r = jnp.asarray(RNG.uniform(0, 2, size=self.P).astype(np.float32))
        self._assert_no_pxp(
            lambda a, b, c, d: ball_counts(
                a, b, c, d, use_kernel=False, block=self.BLOCK
            ).x_lt,
            x, y, m, r,
        )

    def test_fused_estimators_never_materialize(self):
        x, y, m = _sample(self.P)
        for fn in [
            lambda a, b, c: estimators.ksg_mi(a, b, c, k=3),
            lambda a, b, c: estimators.mixed_ksg_mi(a, b, c, k=3),
            lambda a, b, c: estimators.dc_ksg_mi(
                estimators.dense_rank(a, c), b, c, k=3
            ),
        ]:
            self._assert_no_pxp(fn, x, y, m)


class TestEstimatorParity:
    """Fused streaming estimators == seed materialized estimators."""

    @pytest.mark.parametrize("P", [50, 300, 700])
    def test_ksg(self, P):
        x, y, m = _sample(P)
        a = estimators.ksg_mi(x, y, m, k=3, impl="fused")
        b = estimators.ksg_mi(x, y, m, k=3, impl="materialized")
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    @pytest.mark.parametrize("P", [50, 300, 700])
    @pytest.mark.parametrize("k", [1, 3, 5])
    def test_mixed_ksg(self, P, k):
        x, y, m = _sample(P)
        a = estimators.mixed_ksg_mi(x, y, m, k=k, impl="fused")
        b = estimators.mixed_ksg_mi(x, y, m, k=k, impl="materialized")
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    @pytest.mark.parametrize("P", [50, 300, 700])
    def test_dc_ksg(self, P):
        codes = jnp.asarray(RNG.integers(0, 6, size=P).astype(np.int32))
        _, y, m = _sample(P)
        a = estimators.dc_ksg_mi(codes, y, m, k=3, impl="fused")
        b = estimators.dc_ksg_mi(codes, y, m, k=3, impl="materialized")
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    def test_dc_ksg_singleton_classes(self):
        """Classes with one member are excluded in both impls."""
        P = 40
        codes = jnp.asarray(np.arange(P) // 15, jnp.int32)  # class 2 tiny
        _, y, m = _sample(P, tie_frac=0.0)
        a = estimators.dc_ksg_mi(codes, y, m, k=5, impl="fused")
        b = estimators.dc_ksg_mi(codes, y, m, k=5, impl="materialized")
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    @pytest.mark.parametrize("k_i", [1, 2, 4, 5, 8])
    def test_dc_ksg_k_i_any_budget_served(self, k_i):
        """The class-mode kNN buffer widens to max(k, k_i) — a per-point
        budget above k is served (previously a ValueError), identically
        across impls."""
        P = 60
        codes = jnp.asarray(RNG.integers(0, 4, size=P).astype(np.int32))
        _, y, m = _sample(P)
        a = estimators.dc_ksg_mi(codes, y, m, k=3, impl="fused", k_i=k_i)
        b = estimators.dc_ksg_mi(codes, y, m, k=3, impl="materialized",
                                 k_i=k_i)
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    def test_dc_ksg_wide_budget_equals_wide_k(self):
        """k=3 with a widened k_i=6 buffer must read the same radii a
        k=6 call reads — the widening is buffer-only."""
        P = 80
        codes = jnp.asarray(RNG.integers(0, 3, size=P).astype(np.int32))
        _, y, m = _sample(P)
        a = estimators.dc_ksg_mi(codes, y, m, k=3, k_i=6, impl="fused")
        b = estimators.dc_ksg_mi(codes, y, m, k=6, k_i=6, impl="fused")
        assert float(a) == float(b)
        c = estimators.dc_ksg_mi(codes, y, m, k=4, k_i=4)
        d = estimators.dc_ksg_mi(codes, y, m, k=4)
        assert float(c) == float(d)  # default budget == k

    @pytest.mark.parametrize("impl", ["fused", "materialized"])
    def test_dc_ksg_k_i_beyond_lane_cap_rejected(self, impl):
        """Budgets beyond the kernel lane width (ops.K_MAX) cannot be
        buffered on TPU; the clear ValueError remains."""
        from repro.kernels.knn_stats.ops import K_MAX

        P = 40
        codes = jnp.asarray(RNG.integers(0, 4, size=P).astype(np.int32))
        _, y, m = _sample(P)
        with pytest.raises(ValueError, match=f"k_max={K_MAX}"):
            estimators.dc_ksg_mi(codes, y, m, k=3, impl=impl, k_i=K_MAX + 1)

    def test_knn_smallest_k_max_widens_buffer(self):
        """ops-level: k_max returns a wider buffer whose leading k
        columns are bit-identical to the unwidened call."""
        from repro.kernels.knn_stats.ops import knn_smallest

        P = 70
        x, y, m = _sample(P)
        knn3, cnt3 = knn_smallest(x, y, m, k=3, mode="class",
                                  use_kernel=False)
        knn8, cnt8 = knn_smallest(x, y, m, k=3, k_max=8, mode="class",
                                  use_kernel=False)
        assert knn8.shape == (P, 8)
        np.testing.assert_array_equal(np.asarray(knn3),
                                      np.asarray(knn8)[:, :3])
        np.testing.assert_array_equal(np.asarray(cnt3), np.asarray(cnt8))
        with pytest.raises(ValueError, match="k_max"):
            knn_smallest(x, y, m, k=5, k_max=3, use_kernel=False)
        # the K_MAX ceiling is enforced at the ops layer for every
        # backend, not just via dc_ksg_mi's pre-check
        from repro.kernels.knn_stats.ops import K_MAX
        with pytest.raises(ValueError, match="K_MAX"):
            knn_smallest(x, y, m, k=3, k_max=K_MAX + 1, use_kernel=False)


class TestFusedRadiusCountSweep:
    """knn_with_counts == knn_smallest + ball_counts, bit for bit, on
    both the single-tile fused sweep and the multi-tile two-scan path."""

    @pytest.mark.parametrize("P", [7, 64, 128, 200, 513])
    @pytest.mark.parametrize("mode,which", [
        ("joint", "all"), ("joint", "y"), ("class", "y"), ("class", "all"),
    ])
    def test_matches_sequential_ops(self, P, mode, which):
        x, y, m = _sample(P)
        if mode == "class":
            x = jnp.asarray(RNG.integers(0, 5, size=P).astype(np.float32))
        knn1, cnt1 = knn_smallest(x, y, m, k=3, mode=mode, use_kernel=False)
        want = ball_counts(x, y, m, knn1[:, 2], which=which,
                           use_kernel=False)
        knn2, cnt2, got = knn_with_counts(
            x, y, m, k=3, mode=mode, which=which, use_kernel=False
        )
        np.testing.assert_array_equal(np.asarray(knn1), np.asarray(knn2))
        np.testing.assert_array_equal(np.asarray(cnt1), np.asarray(cnt2))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_custom_radius_callback(self):
        """A caller-supplied radius (DC-KSG's clipped extraction) is
        applied inside the same sweep."""
        P = 64
        x, y, m = _sample(P)

        def r_fn(knn, cnt):
            return knn[:, 0]  # 1-NN radius instead of k-th

        knn, _, got = knn_with_counts(
            x, y, m, k=3, radius=r_fn, use_kernel=False
        )
        want = ball_counts(x, y, m, knn[:, 0], use_kernel=False)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_kernel_path_dispatches_both_kernels(self):
        """On the (interpreted) TPU kernel path the fused wrapper equals
        the sequential kernel calls too."""
        P = 64
        x, y, m = _sample(P)
        knn1, cnt1 = knn_smallest(x, y, m, k=3, use_kernel=True, block=128)
        want = ball_counts(x, y, m, knn1[:, 2], use_kernel=True, block=128)
        knn2, _, got = knn_with_counts(
            x, y, m, k=3, use_kernel=True, block=128
        )
        np.testing.assert_array_equal(np.asarray(knn1), np.asarray(knn2))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_single_sweep_has_one_topk(self):
        """The fused single-tile sweep lowers exactly one top_k and no
        scan — the two-pass structure is gone from the jaxpr."""
        P = 64
        x, y, m = _sample(P)
        jaxpr = str(jax.make_jaxpr(
            lambda a, b, c: knn_with_counts(a, b, c, k=3, use_kernel=False)
        )(x, y, m))
        assert jaxpr.count("top_k") == 1
        assert "scan" not in jaxpr


class TestSingleKernelRadiusCounts:
    """knn_radius_counts: the single-pallas_call radius+count path is
    bit-identical to the two-op kernel composition AND to the naive
    materialized oracle, across edge shapes (P < block, P not a multiple
    of block, k == K_MAX) — the contract that let the estimators drop
    the separate count kernel."""

    @staticmethod
    def _oracle(x, y, m, *, k, mode="joint", kb=None, kkv=None):
        """Radius, class count and ball counts from the ref.py oracles."""
        kb = kb or k
        kkv = kkv or k
        knn_r, cnt_r = knn_smallest_ref(x, y, m, k=kb, mode=mode)
        knn_np = np.asarray(knn_r)
        if mode == "joint":
            r = knn_np[:, k - 1]
        else:
            n_x = np.asarray(cnt_r) + np.asarray(m).astype(np.int32)
            idx = np.clip(np.minimum(kkv, n_x - 1) - 1, 0, kb - 1)
            r = np.take_along_axis(knn_np, idx[:, None], axis=1)[:, 0]
        counts = ball_counts_ref(x, y, m, jnp.asarray(r))
        return r, np.asarray(cnt_r), counts

    @pytest.mark.parametrize("P,block", [
        (200, 256),   # P < block: one padded tile, the fast path
        (300, 128),   # P not a multiple of block: multi-tile second pass
        (64, 64),     # exact fit
        (513, 256),   # odd P, multi-tile
    ])
    @pytest.mark.parametrize("mode", ["joint", "class"])
    def test_edge_shapes_vs_oracle(self, P, block, mode):
        x, y, m = _sample(P)
        which = "all" if mode == "joint" else "y"
        if mode == "class":
            x = jnp.asarray(RNG.integers(0, 5, size=P).astype(np.float32))
        r, cnt, counts = knn_radius_counts(
            x, y, m, k=3, mode=mode, which=which, use_kernel=True,
            block=block,
        )
        r_w, cnt_w, counts_w = self._oracle(x, y, m, k=3, mode=mode)
        np.testing.assert_array_equal(np.asarray(r), r_w)
        np.testing.assert_array_equal(np.asarray(cnt), cnt_w)
        if which == "y":
            np.testing.assert_array_equal(
                np.asarray(counts.y_lt), np.asarray(counts_w[1])
            )
            for f in (counts.x_lt, counts.x_eq, counts.y_eq, counts.j_eq):
                assert not np.any(np.asarray(f))
        else:
            for g, w in zip(counts, counts_w):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_k_equals_lane_width(self):
        """k == K_MAX saturates the (bm, LANES) buffer: the widest radius
        any backend can serve still matches the materialized oracle."""
        P = 160
        x, y, m = _sample(P)
        r, _, counts = knn_radius_counts(
            x, y, m, k=K_MAX, mode="joint", use_kernel=True, block=256
        )
        r_w, _, counts_w = self._oracle(x, y, m, k=K_MAX)
        np.testing.assert_array_equal(np.asarray(r), r_w)
        for g, w in zip(counts, counts_w):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("P,block", [(256, 256), (300, 128)])
    def test_matches_two_op_kernel_path(self, P, block):
        """Bit-identity against the kernel-path two-op composition — the
        acceptance contract of the single-kernel port."""
        x, y, m = _sample(P)
        knn, cnt0, want = knn_with_counts(
            x, y, m, k=4, use_kernel=True, block=block
        )
        r, cnt1, got = knn_radius_counts(
            x, y, m, k=4, use_kernel=True, block=block
        )
        np.testing.assert_array_equal(np.asarray(knn)[:, 3], np.asarray(r))
        np.testing.assert_array_equal(np.asarray(cnt0), np.asarray(cnt1))
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_class_budget_wider_than_k(self):
        """The widened-buffer DC-KSG case (kk > k) rides the kernel."""
        P = 128
        x = jnp.asarray(RNG.integers(0, 3, size=P).astype(np.float32))
        y, m = _sample(P)[1:]
        r, cnt, counts = knn_radius_counts(
            x, y, m, k=3, k_max=16, kk=9, mode="class", which="y",
            use_kernel=True, block=128,
        )
        r_w, cnt_w, counts_w = self._oracle(
            x, y, m, k=3, mode="class", kb=16, kkv=9
        )
        np.testing.assert_array_equal(np.asarray(r), r_w)
        np.testing.assert_array_equal(np.asarray(cnt), cnt_w)
        np.testing.assert_array_equal(
            np.asarray(counts.y_lt), np.asarray(counts_w[1])
        )

    def test_one_pallas_call(self):
        """The fused path lowers exactly one pallas_call where the two-op
        composition lowers two — the kernel-count claim, on the jaxpr."""
        P = 256
        x, y, m = _sample(P)
        fused = str(jax.make_jaxpr(
            lambda a, b, c: knn_radius_counts(
                a, b, c, k=4, use_kernel=True, block=256
            )
        )(x, y, m))
        two_op = str(jax.make_jaxpr(
            lambda a, b, c: knn_with_counts(
                a, b, c, k=4, use_kernel=True, block=256
            )
        )(x, y, m))
        assert fused.count("pallas_call") == 1
        assert two_op.count("pallas_call") == 2

    def test_kk_beyond_buffer_rejected(self):
        x, y, m = _sample(32)
        with pytest.raises(ValueError, match="kk=9"):
            knn_radius_counts(x, y, m, k=3, k_max=4, kk=9, mode="class",
                              use_kernel=False)
