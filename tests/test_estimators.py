"""MI estimator correctness against closed-form ground truths."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import estimators, synthetic

RNG = np.random.default_rng(7)


def _mask(n, pad=0):
    return jnp.asarray(np.r_[np.ones(n, bool), np.zeros(pad, bool)])


def _pad(a, pad=0):
    return jnp.asarray(np.r_[a, np.zeros(pad, a.dtype)])


class TestMLE:
    def test_independent_is_zero(self):
        x = RNG.integers(0, 4, size=4000)
        y = RNG.integers(0, 4, size=4000)
        mi = estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(4000))
        # bias ~ (mx*my - mx - my + 1)/2N ≈ 9/8000
        assert float(mi) < 0.02

    def test_identity_is_entropy(self):
        x = RNG.integers(0, 8, size=5000)
        h = estimators.discrete_entropy(jnp.asarray(x), _mask(5000))
        mi = estimators.mle_mi(jnp.asarray(x), jnp.asarray(x), _mask(5000))
        assert float(mi) == pytest.approx(float(h), rel=1e-5)
        assert float(h) == pytest.approx(np.log(8), rel=0.02)

    def test_padding_invariance(self):
        x = RNG.integers(0, 5, size=300)
        y = (x + RNG.integers(0, 2, size=300)) % 5
        a = estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(300))
        b = estimators.mle_mi(_pad(x, 100), _pad(y, 100), _mask(300, 100))
        assert float(a) == pytest.approx(float(b), abs=1e-6)

    def test_symmetry(self):
        x = RNG.integers(0, 6, size=500)
        y = RNG.integers(0, 3, size=500)
        a = estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(500))
        b = estimators.mle_mi(jnp.asarray(y), jnp.asarray(x), _mask(500))
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    def test_uint32_codes_no_truncation(self):
        # Codes above 2^31 must not collide through int32 truncation.
        x = np.array([0x80000001, 0x00000001] * 200, dtype=np.uint32)
        y = np.array([1, 2] * 200, dtype=np.uint32)
        mi = estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(400))
        assert float(mi) == pytest.approx(np.log(2), rel=1e-3)

    @given(st.integers(0, 2**31))
    @settings(max_examples=20, deadline=None)
    def test_nonnegative(self, seed):
        r = np.random.default_rng(seed)
        n = int(r.integers(8, 200))
        x = r.integers(0, 10, size=n)
        y = r.integers(0, 10, size=n)
        mi = estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(n))
        assert float(mi) >= 0.0


class TestKSG:
    def test_bivariate_gaussian(self):
        """KSG on correlated gaussians vs closed form −½ln(1−r²)."""
        for r in [0.0, 0.5, 0.9]:
            n = 2000
            z = RNG.multivariate_normal([0, 0], [[1, r], [r, 1]], size=n)
            mi = estimators.ksg_mi(
                jnp.asarray(z[:, 0], jnp.float32),
                jnp.asarray(z[:, 1], jnp.float32),
                _mask(n),
            )
            expected = -0.5 * np.log(1 - r**2)
            assert float(mi) == pytest.approx(expected, abs=0.1), r

    def test_padding_invariance(self):
        n = 300
        z = RNG.multivariate_normal([0, 0], [[1, 0.7], [0.7, 1]], size=n)
        x, y = z[:, 0].astype(np.float32), z[:, 1].astype(np.float32)
        a = estimators.ksg_mi(jnp.asarray(x), jnp.asarray(y), _mask(n))
        b = estimators.ksg_mi(_pad(x, 212), _pad(y, 212), _mask(n, 212))
        assert float(a) == pytest.approx(float(b), abs=1e-4)


class TestMixedKSG:
    def test_cdunif(self):
        """MixedKSG on the paper's CDUnif: discrete X, continuous Y with
        repeated-value plateaus — the estimator's home turf."""
        for m in [4, 16, 64]:
            pair = synthetic.gen_cdunif(3000, m, RNG)
            mi = estimators.mixed_ksg_mi(
                jnp.asarray(pair.x, jnp.float32),
                jnp.asarray(pair.y),
                _mask(3000),
            )
            assert float(mi) == pytest.approx(pair.true_mi, abs=0.15), m

    def test_gaussian_matches_ksg_regime(self):
        n = 1500
        z = RNG.multivariate_normal([0, 0], [[1, 0.8], [0.8, 1]], size=n)
        mi = estimators.mixed_ksg_mi(
            jnp.asarray(z[:, 0], jnp.float32),
            jnp.asarray(z[:, 1], jnp.float32),
            _mask(n),
        )
        assert float(mi) == pytest.approx(-0.5 * np.log(1 - 0.64), abs=0.12)

    def test_padding_invariance(self):
        pair = synthetic.gen_cdunif(400, 8, RNG)
        x = pair.x.astype(np.float32)
        a = estimators.mixed_ksg_mi(jnp.asarray(x), jnp.asarray(pair.y), _mask(400))
        b = estimators.mixed_ksg_mi(_pad(x, 112), _pad(pair.y, 112), _mask(400, 112))
        assert float(a) == pytest.approx(float(b), abs=1e-4)


class TestDCKSG:
    def test_cdunif(self):
        for m in [4, 16]:
            pair = synthetic.gen_cdunif(3000, m, RNG)
            mi = estimators.dc_ksg_mi(
                jnp.asarray(pair.x.astype(np.int32)),
                jnp.asarray(pair.y),
                _mask(3000),
            )
            assert float(mi) == pytest.approx(pair.true_mi, abs=0.2), m

    def test_independent_near_zero(self):
        x = RNG.integers(0, 5, size=2000).astype(np.int32)
        y = RNG.normal(size=2000).astype(np.float32)
        mi = estimators.dc_ksg_mi(jnp.asarray(x), jnp.asarray(y), _mask(2000))
        assert float(mi) < 0.05


class TestDispatch:
    def test_routes(self):
        pair = synthetic.gen_cdunif(500, 8, RNG)
        x = jnp.asarray(pair.x.astype(np.uint32))
        xf = jnp.asarray(pair.x.astype(np.float32))
        y = jnp.asarray(pair.y)
        m = _mask(500)
        via_auto = estimators.estimate_mi(x, y, m, x_discrete=True, y_discrete=False)
        via_dc = estimators.dc_ksg_mi(estimators.dense_rank(x, m), y, m)
        assert float(via_auto) == pytest.approx(float(via_dc), abs=1e-5)
        both_cont = estimators.estimate_mi(
            xf, y, m, x_discrete=False, y_discrete=False
        )
        via_mixed = estimators.mixed_ksg_mi(xf, y, m)
        assert float(both_cont) == pytest.approx(float(via_mixed), abs=1e-5)

    def test_small_sample_guard(self):
        m = _mask(2, 6)
        x = jnp.asarray(np.zeros(8, np.float32))
        assert float(estimators.ksg_mi(x, x, m)) == 0.0
        assert float(estimators.mixed_ksg_mi(x, x, m)) == 0.0


class TestSmoothedMLE:
    """Laplace-smoothed MI (the paper's conclusion: controls false
    discoveries where raw MLE 'offers high recall')."""

    def test_shrinks_false_positives(self):
        # independent, many distinct values, small sample — raw MLE's
        # worst case (bias ≈ m_x·m_y/2N)
        x = RNG.integers(0, 30, size=200)
        y = RNG.integers(0, 30, size=200)
        raw = float(estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(200)))
        smooth = float(estimators.mle_mi_smoothed(
            jnp.asarray(x), jnp.asarray(y), _mask(200)))
        assert raw > 0.5  # the false discovery
        assert smooth < raw * 0.25

    def test_preserves_true_dependence(self):
        x = RNG.integers(0, 4, size=2000)
        y = (x + RNG.integers(0, 2, size=2000)) % 4
        raw = float(estimators.mle_mi(jnp.asarray(x), jnp.asarray(y), _mask(2000)))
        smooth = float(estimators.mle_mi_smoothed(
            jnp.asarray(x), jnp.asarray(y), _mask(2000)))
        assert smooth == pytest.approx(raw, abs=0.05)

    def test_padding_invariance(self):
        x = RNG.integers(0, 5, size=300)
        y = (x * 2 + RNG.integers(0, 2, size=300)) % 5
        a = estimators.mle_mi_smoothed(jnp.asarray(x), jnp.asarray(y), _mask(300))
        b = estimators.mle_mi_smoothed(_pad(x, 100), _pad(y, 100), _mask(300, 100))
        assert float(a) == pytest.approx(float(b), abs=1e-5)

    def test_dispatch(self):
        x = RNG.integers(0, 4, size=100)
        via = estimators.estimate_mi(
            jnp.asarray(x), jnp.asarray(x), _mask(100),
            x_discrete=True, y_discrete=True, method="mle_smoothed",
        )
        assert float(via) > 1.0


class TestDenseRank:
    @given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
    @settings(max_examples=30, deadline=None)
    def test_rank_faithful(self, vals):
        arr = np.asarray(vals, dtype=np.uint32)
        r = np.asarray(estimators.dense_rank(jnp.asarray(arr), _mask(len(arr))))
        # equal values share ranks; distinct values get distinct ranks
        for i in range(len(arr)):
            for j in range(len(arr)):
                assert (r[i] == r[j]) == (arr[i] == arr[j])
        # ranks are dense starting at 0
        assert set(r.tolist()) == set(range(len(np.unique(arr))))
